"""RWKV-6 (Finch) token mixing — data-dependent decay linear attention.

TPU adaptation (DESIGN.md §3): the reference CUDA wkv kernel is replaced by
a *chunked* linear-attention formulation that turns the per-token recurrence

    S_t = diag(w_t) S_{t-1} + k_t^T v_t ,   o_t = r_t (S_{t-1} + diag(u) k_t^T v_t)

into per-chunk MXU matmuls (intra-chunk lower-triangular attention with
cumulative-decay rescaling, inter-chunk state carried by lax.scan).  A naive
per-token lax.scan implementation is kept as the reference oracle
(`wkv_naive`) and for single-token decode.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.common import dense_init, rms_norm


def init_rwkv_mix(key, cfg, dtype):
    D = cfg.d_model
    hs = cfg.rwkv.head_size
    H = D // hs
    r = cfg.rwkv.decay_lora
    ks = jax.random.split(key, 10)
    return {
        "mu_r": jnp.full((D,), 0.5, dtype), "mu_k": jnp.full((D,), 0.5, dtype),
        "mu_v": jnp.full((D,), 0.5, dtype), "mu_w": jnp.full((D,), 0.5, dtype),
        "mu_g": jnp.full((D,), 0.5, dtype),
        "wr": dense_init(ks[0], (D, D), dtype),
        "wk": dense_init(ks[1], (D, D), dtype),
        "wv": dense_init(ks[2], (D, D), dtype),
        "wg": dense_init(ks[3], (D, D), dtype),
        "wo": dense_init(ks[4], (D, D), dtype),
        # data-dependent decay LoRA:  w = exp(-exp(w0 + tanh(x A) B))
        "w0": jnp.full((D,), -5.0, jnp.float32),
        "wA": dense_init(ks[5], (D, r), dtype),
        "wB": dense_init(ks[6], (r, D), dtype, scale=0.01),
        "u": dense_init(ks[7], (H, hs), jnp.float32, scale=0.3),
        "ln_x": jnp.ones((D,), dtype),
    }


def _shift(x, x_prev):
    """Token shift: concat last token of previous step. x: (B,S,D)."""
    return jnp.concatenate([x_prev[:, None, :], x[:, :-1, :]], axis=1)


def _proj_rkvwg(p, x, x_prev, cfg):
    xs = _shift(x, x_prev)
    def lerp(mu):
        return x + (xs - x) * mu
    r = lerp(p["mu_r"]) @ p["wr"]
    k = lerp(p["mu_k"]) @ p["wk"]
    v = lerp(p["mu_v"]) @ p["wv"]
    g = jax.nn.silu(lerp(p["mu_g"]) @ p["wg"])
    lw = lerp(p["mu_w"]).astype(jnp.float32)
    w = jnp.exp(-jnp.exp(p["w0"] + jnp.tanh(lw @ p["wA"].astype(jnp.float32))
                         @ p["wB"].astype(jnp.float32)))
    return r, k, v, g, w          # w in (0,1), f32


def _heads(x, H, hs):
    B, S, _ = x.shape
    return x.reshape(B, S, H, hs)


def wkv_naive(r, k, v, w, u, state):
    """Per-token recurrence (oracle + decode path).

    r,k,v: (B,S,H,hs); w: (B,S,H,hs) decay; u: (H,hs) bonus;
    state: (B,H,hs,hs)  ->  (out (B,S,H,hs), state)
    """
    rf, kf, vf, wf = (t.astype(jnp.float32) for t in (r, k, v, w))

    def step(s, xs):
        r_t, k_t, v_t, w_t = xs              # (B,H,hs)
        kv = k_t[..., :, None] * v_t[..., None, :]          # (B,H,hs,hs)
        o = jnp.einsum("bhk,bhkv->bhv", r_t, s + u[..., None] * kv)
        s = w_t[..., None] * s + kv
        return s, o

    xs = tuple(jnp.moveaxis(t, 1, 0) for t in (rf, kf, vf, wf))
    state, out = jax.lax.scan(step, state, xs)
    return jnp.moveaxis(out, 0, 1).astype(r.dtype), state


def wkv_chunked(r, k, v, w, u, state, chunk: int = 64):
    """Chunked parallel form — MXU-friendly (see module docstring).

    Within a chunk of length C (A = cumprod of w inclusive):
      o_j  = (r_j * A_{j-1}) S_0  +  sum_{t<j} (r_j*A_{j-1}/A_t * k_t) v_t
             + (r_j * u * k_j) v_j
      S_C  = diag(A_C) S_0 + sum_t diag(A_C/A_t) k_t^T v_t
    """
    B, S, H, hs = r.shape
    assert S % chunk == 0, (S, chunk)
    C = chunk
    n = S // C
    rf, kf, vf, wf = (jnp.moveaxis(t.astype(jnp.float32), 1, 2)
                      .reshape(B, H, n, C, hs)
                      for t in (r, k, v, w))

    def step(s, xs):
        r_c, k_c, v_c, w_c = xs                        # (B,H,C,hs)
        logw = jnp.log(jnp.maximum(w_c, 1e-12))
        la = jnp.cumsum(logw, axis=-2)                 # log A_j (inclusive)
        a_incl = jnp.exp(la)                           # A_j
        a_excl = jnp.exp(la - logw)                    # A_{j-1}
        r_dec = r_c * a_excl
        k_div = k_c * jnp.exp(-la)                     # k_t / A_t
        # intra-chunk strict-lower attention
        att = jnp.einsum("bhik,bhjk->bhij", r_dec, k_div)
        att = jnp.tril(att, k=-1)
        intra = jnp.einsum("bhij,bhjv->bhiv", att, v_c)
        # diagonal bonus term
        bonus = jnp.einsum("bhik,bhik->bhi", r_c * u[None, :, None, :], k_c)
        intra = intra + bonus[..., None] * v_c
        # inter-chunk: state contribution
        inter = jnp.einsum("bhik,bhkv->bhiv", r_dec, s)
        # state update
        a_tot = a_incl[..., -1:, :]                    # (B,H,1,hs)
        k_scaled = k_c * (a_tot / jnp.maximum(a_incl, 1e-30))
        s_new = a_tot.squeeze(-2)[..., None] * s + jnp.einsum(
            "bhik,bhiv->bhkv", k_scaled, v_c)
        return s_new, intra + inter

    xs = tuple(jnp.moveaxis(t, 2, 0) for t in (rf, kf, vf, wf))
    state, out = jax.lax.scan(step, state, xs)         # out: (n,B,H,C,hs)
    out = jnp.moveaxis(out, 0, 2).reshape(B, H, S, hs)
    return jnp.moveaxis(out, 1, 2).astype(r.dtype), state


def rwkv_mix_train(p, x, x_prev, state, cfg, chunked: bool = True):
    """x: (B,S,D); x_prev: (B,D) last token of previous segment;
    state: (B,H,hs,hs).  Returns (out, (x_last, state))."""
    B, S, D = x.shape
    hs = cfg.rwkv.head_size
    H = D // hs
    r, k, v, g, w = _proj_rkvwg(p, x, x_prev, cfg)
    rh, kh, vh, wh = (_heads(t, H, hs) for t in (r, k, v, w))
    if chunked and S % 64 == 0 and S > 1:
        out, state = wkv_chunked(rh, kh, vh, wh, p["u"], state)
    else:
        out, state = wkv_naive(rh, kh, vh, wh, p["u"], state)
    out = out.reshape(B, S, D)
    out = rms_norm(out, p["ln_x"], cfg.norm_eps) * g
    return out @ p["wo"], (x[:, -1, :], state)


def init_rwkv_state(cfg, batch: int, dtype):
    hs = cfg.rwkv.head_size
    H = cfg.d_model // hs
    return {"x_prev_mix": jnp.zeros((batch, cfg.d_model), dtype),
            "x_prev_ffn": jnp.zeros((batch, cfg.d_model), dtype),
            "wkv": jnp.zeros((batch, H, hs, hs), jnp.float32)}


def init_rwkv_ffn(key, cfg, dtype):
    D, F = cfg.d_model, cfg.d_ff
    ks = jax.random.split(key, 3)
    return {
        "mu_k": jnp.full((D,), 0.5, dtype), "mu_r": jnp.full((D,), 0.5, dtype),
        "wk": dense_init(ks[0], (D, F), dtype),
        "wv": dense_init(ks[1], (F, D), dtype),
        "wr": dense_init(ks[2], (D, D), dtype),
    }


def rwkv_ffn(p, x, x_prev, cfg):
    """RWKV channel-mix.  Returns (out, x_last)."""
    xs = _shift(x, x_prev)
    xk = x + (xs - x) * p["mu_k"]
    xr = x + (xs - x) * p["mu_r"]
    h = jnp.square(jax.nn.relu(xk @ p["wk"]))
    return jax.nn.sigmoid(xr @ p["wr"]) * (h @ p["wv"]), x[:, -1, :]
