"""Mamba-1 selective SSM block (Jamba's recurrent mixer).

TPU adaptation note (DESIGN.md §3): Mamba's per-(channel, state) decay
a_t = exp(dt_t * A) prevents the rank-1 chunked-matmul trick that works for
RWKV-6 (decay there is shared across the value dim).  The baseline here is a
sequential lax.scan over time at state granularity — O(S) steps, O(1) memory
beyond activations — with a *chunk-blocked* variant (scan over chunks, inner
associative materialization of (C, d_inner, N)) as the perf knob.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.common import dense_init


def _dt_rank(cfg) -> int:
    return cfg.ssm.dt_rank or -(-cfg.d_model // 16)


def init_mamba(key, cfg, dtype):
    D = cfg.d_model
    s = cfg.ssm
    din = s.expand * D
    N = s.d_state
    R = _dt_rank(cfg)
    ks = jax.random.split(key, 6)
    A = jnp.tile(jnp.arange(1, N + 1, dtype=jnp.float32)[None, :], (din, 1))
    return {
        "in_proj": dense_init(ks[0], (D, 2 * din), dtype),
        "conv_w": dense_init(ks[1], (s.d_conv, din), dtype, scale=0.5),
        "conv_b": jnp.zeros((din,), dtype),
        "x_proj": dense_init(ks[2], (din, R + 2 * N), dtype),
        "dt_proj_w": dense_init(ks[3], (R, din), dtype),
        "dt_proj_b": jnp.full((din,), -4.6, jnp.float32),   # softplus^-1(0.01)
        "A_log": jnp.log(A),
        "D": jnp.ones((din,), jnp.float32),
        "out_proj": dense_init(ks[4], (din, D), dtype),
    }


def _conv_causal(x, w, b, conv_state=None):
    """Depthwise causal conv.  x: (B,S,din), w: (K,din).

    conv_state: (B, K-1, din) trailing inputs from the previous segment
    (decode); returns (y, new_conv_state).
    """
    K = w.shape[0]
    B, S, din = x.shape
    if conv_state is None:
        conv_state = jnp.zeros((B, K - 1, din), x.dtype)
    xp = jnp.concatenate([conv_state, x], axis=1)          # (B, S+K-1, din)
    y = sum(xp[:, i:i + S, :] * w[i][None, None, :] for i in range(K))
    new_state = xp[:, S:, :] if False else xp[:, -(K - 1):, :]
    return jax.nn.silu(y + b), new_state


def _ssm_scan(u, dt, B_t, C_t, A, D, h0):
    """Selective scan.  u, dt: (B,S,din); B_t, C_t: (B,S,N); A: (din,N).

    h_t = exp(dt_t A) * h_{t-1} + (dt_t * u_t) outer B_t ;  y_t = h_t . C_t
    Returns (y (B,S,din), h (B,din,N)).
    """
    dtA = dt[..., None] * A[None, None]                    # (B,S,din,N)
    decay = jnp.exp(dtA)
    inp = (dt * u)[..., None] * B_t[:, :, None, :]         # (B,S,din,N)

    def step(h, xs):
        d_t, i_t, c_t = xs                                 # (B,din,N),(B,N)
        h = d_t * h + i_t
        y = jnp.einsum("bdn,bn->bd", h, c_t)
        return h, y

    xs = (jnp.moveaxis(decay, 1, 0), jnp.moveaxis(inp, 1, 0),
          jnp.moveaxis(C_t, 1, 0))
    h, ys = jax.lax.scan(step, h0, xs)
    y = jnp.moveaxis(ys, 0, 1) + u * D[None, None]
    return y, h


def _ssm_chunked(u, dt, B_t, C_t, A, D, h0, chunk: int = 128):
    """Chunk-blocked scan: sequential over S/chunk super-steps, the inner
    chunk materializes cumulative decays and uses cumsum-style parallel form.
    Same math as _ssm_scan (validated in tests).

    §Perf note: the (C, din, N) decay/input blocks are computed INSIDE the
    checkpointed chunk body from the (C, din) / (C, N) raw projections —
    materializing them over the full sequence (the naive formulation) costs
    O(S·din·N) residuals per layer and forced multi-GB reshards on the
    sharded d_inner axis (measured: 4.67 TB/device temp on jamba train_4k;
    see EXPERIMENTS.md §Perf iteration 1)."""
    B, S, din = u.shape
    N = B_t.shape[-1]
    assert S % chunk == 0
    C = chunk
    n = S // C
    uc = u.reshape(B, n, C, din)
    dtc = dt.reshape(B, n, C, din)
    Bc = B_t.reshape(B, n, C, N)
    Cc = C_t.reshape(B, n, C, N)

    @jax.checkpoint
    def step(h, xs):
        u_c, dt_c, b_c, c_c = xs          # (B,C,din), (B,C,N)
        la = dt_c[..., None] * A[None, None]            # (B,C,din,N) log-dec
        i_c = (dt_c * u_c)[..., None] * b_c[:, :, None, :]
        cum = jnp.cumsum(la, axis=1)      # inclusive log cumprod
        # h_j = exp(cum_j) h0 + sum_{t<=j} exp(cum_j - cum_t) i_t
        w = jnp.exp(cum)
        scaled = i_c * jnp.exp(-cum)
        acc = jnp.cumsum(scaled, axis=1)
        h_all = w * (h[:, None] + acc)    # (B,C,din,N)
        y = jnp.einsum("bcdn,bcn->bcd", h_all, c_c)
        return h_all[:, -1], y

    xs = (jnp.moveaxis(uc, 1, 0), jnp.moveaxis(dtc, 1, 0),
          jnp.moveaxis(Bc, 1, 0), jnp.moveaxis(Cc, 1, 0))
    h, ys = jax.lax.scan(step, h0, xs)
    y = jnp.moveaxis(ys, 0, 1).reshape(B, S, din) + u * D[None, None]
    return y, h


def init_mamba_state(cfg, batch: int, dtype):
    din = cfg.ssm.expand * cfg.d_model
    return {"conv": jnp.zeros((batch, cfg.ssm.d_conv - 1, din), dtype),
            "h": jnp.zeros((batch, din, cfg.ssm.d_state), jnp.float32)}


def mamba_block(p, x, state, cfg, chunked: bool = False):
    """x: (B,S,D), state: {conv, h}.  Returns (out, new_state)."""
    s = cfg.ssm
    N = s.d_state
    R = _dt_rank(cfg)
    xz = x @ p["in_proj"]
    u, z = jnp.split(xz, 2, axis=-1)                       # (B,S,din)
    u, conv_state = _conv_causal(u, p["conv_w"], p["conv_b"], state["conv"])
    proj = u @ p["x_proj"]
    dt_r, B_t, C_t = jnp.split(proj, [R, R + N], axis=-1)
    dt = jax.nn.softplus(
        dt_r.astype(jnp.float32) @ p["dt_proj_w"].astype(jnp.float32)
        + p["dt_proj_b"])                                  # (B,S,din) f32
    A = -jnp.exp(p["A_log"])                               # (din,N), negative
    uf = u.astype(jnp.float32)
    Bf, Cf = B_t.astype(jnp.float32), C_t.astype(jnp.float32)
    if chunked and x.shape[1] % 128 == 0 and x.shape[1] > 1:
        y, h = _ssm_chunked(uf, dt, Bf, Cf, A, p["D"], state["h"])
    else:
        y, h = _ssm_scan(uf, dt, Bf, Cf, A, p["D"], state["h"])
    out = (y.astype(x.dtype) * jax.nn.silu(z)) @ p["out_proj"]
    return out, {"conv": conv_state, "h": h}
