"""DeepSeek-V2 Multi-head Latent Attention (MLA).

The KV cache stores only the compressed latent c_kv (rank `kv_lora_rank`)
plus the shared RoPE key — ~10x smaller than a GQA cache.  The baseline
decode path decompresses K/V from the latent each step (matches the paper's
formulation); absorbing W_uk into the query is a §Perf optimization measured
in EXPERIMENTS.md.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.common import apply_rope, dense_init, rms_norm
from repro.models.attention import _flash


def init_mla(key, cfg, dtype):
    D, H = cfg.d_model, cfg.n_heads
    m = cfg.mla
    ks = jax.random.split(key, 5)
    qk = m.qk_nope_head_dim + m.qk_rope_head_dim
    return {
        "wq": dense_init(ks[0], (D, H, qk), dtype),
        "w_dkv": dense_init(ks[1], (D, m.kv_lora_rank + m.qk_rope_head_dim), dtype),
        "kv_norm": jnp.ones((m.kv_lora_rank,), dtype),
        "w_uk": dense_init(ks[2], (m.kv_lora_rank, H, m.qk_nope_head_dim), dtype),
        "w_uv": dense_init(ks[3], (m.kv_lora_rank, H, m.v_head_dim), dtype),
        "wo": dense_init(ks[4], (H, m.v_head_dim, D), dtype),
    }


def _q_proj(p, x, positions, cfg):
    m = cfg.mla
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    q_nope, q_pe = jnp.split(q, [m.qk_nope_head_dim], axis=-1)
    q_pe = apply_rope(q_pe, positions, cfg.rope_theta)
    return jnp.concatenate([q_nope, q_pe], axis=-1)


def _latent(p, x, positions, cfg):
    m = cfg.mla
    ckv = x @ p["w_dkv"]                                    # (B,S,lora+rope)
    c, k_pe = jnp.split(ckv, [m.kv_lora_rank], axis=-1)
    c = rms_norm(c, p["kv_norm"], cfg.norm_eps)
    k_pe = apply_rope(k_pe, positions, cfg.rope_theta)      # (B,S,rope)
    return c, k_pe


def _decompress(p, c, k_pe, cfg):
    """latent -> per-head K (nope+rope) and V."""
    H = cfg.n_heads
    k_nope = jnp.einsum("bsl,lhk->bshk", c, p["w_uk"])
    v = jnp.einsum("bsl,lhk->bshk", c, p["w_uv"])
    k_pe_b = jnp.broadcast_to(k_pe[:, :, None, :],
                              k_nope.shape[:3] + (k_pe.shape[-1],))
    k = jnp.concatenate([k_nope, k_pe_b], axis=-1)
    return k, v


def mla_train(p, x, positions, cfg, window: int = 0):
    q = _q_proj(p, x, positions[None, :], cfg)
    c, k_pe = _latent(p, x, positions[None, :], cfg)
    k, v = _decompress(p, c, k_pe, cfg)
    win = window if window else cfg.swa_window
    out = _flash(q, k, v, positions, positions, win)        # kv heads == H
    return jnp.einsum("bshk,hkd->bsd", out, p["wo"])


def init_mla_cache(cfg, batch: int, max_seq: int, dtype, window: int = 0):
    m = cfg.mla
    slots = min(max_seq, window) if window > 0 else max_seq
    return {"c": jnp.zeros((batch, slots, m.kv_lora_rank), dtype),
            "kpe": jnp.zeros((batch, slots, m.qk_rope_head_dim), dtype),
            "pos": jnp.full((slots,), -1, jnp.int32)}


def mla_prefill(p, x, positions, cfg, cache, window: int = 0):
    q = _q_proj(p, x, positions[None, :], cfg)
    c, k_pe = _latent(p, x, positions[None, :], cfg)
    k, v = _decompress(p, c, k_pe, cfg)
    win = window if window else cfg.swa_window
    out = _flash(q, k, v, positions, positions, win)
    S = x.shape[1]
    slots = cache["c"].shape[1]
    if slots >= S:
        cc = jax.lax.dynamic_update_slice(cache["c"], c, (0, 0, 0))
        ck = jax.lax.dynamic_update_slice(cache["kpe"], k_pe, (0, 0, 0))
        cp = jax.lax.dynamic_update_slice(
            cache["pos"], positions.astype(jnp.int32), (0,))
    else:
        cc, ck = c[:, S - slots:], k_pe[:, S - slots:]
        cp = positions[S - slots:].astype(jnp.int32)
    return (jnp.einsum("bshk,hkd->bsd", out, p["wo"]),
            {"c": cc, "kpe": ck, "pos": cp})


def mla_decode(p, x, pos, cfg, cache, window: int = 0):
    positions = jnp.full((1, 1), pos, jnp.int32)
    q = _q_proj(p, x, positions, cfg)
    c, k_pe = _latent(p, x, positions, cfg)
    slots = cache["c"].shape[1]
    win = window if window else cfg.swa_window
    slot = jnp.where(win > 0, pos % slots, jnp.minimum(pos, slots - 1))
    cc = jax.lax.dynamic_update_slice(cache["c"], c, (0, slot, 0))
    ck = jax.lax.dynamic_update_slice(cache["kpe"], k_pe, (0, slot, 0))
    cp = jax.lax.dynamic_update_slice(
        cache["pos"], jnp.full((1,), pos, jnp.int32), (slot,))
    k, v = _decompress(p, cc, ck, cfg)                      # baseline path
    out = _flash(q, k, v, jnp.full((1,), pos, jnp.int32), cp, win)
    return (jnp.einsum("bshk,hkd->bsd", out, p["wo"]),
            {"c": cc, "kpe": ck, "pos": cp})
