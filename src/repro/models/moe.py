"""Mixture-of-Experts FFN with GShard/Switch-style capacity dispatch.

Capacity-based dispatch keeps the compiled FLOPs equal to the *active*
FLOPs (tokens x top_k x expert FFN), which is what the roofline analysis
must see — a dense all-experts einsum would overstate MoE compute by
E/top_k.  Experts are tensor-parallel over the `model` axis within each
expert (uniform across 8/16/64-expert configs), dispatch is batch-sharded.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.common import dense_init


def init_moe(key, cfg, dtype):
    m = cfg.moe
    D, F, E = cfg.d_model, (m.d_ff_expert or cfg.d_ff), m.num_experts
    ks = jax.random.split(key, 5)
    p = {
        "router": dense_init(ks[0], (D, E), jnp.float32, scale=0.02),
        "w1": dense_init(ks[1], (E, D, F), dtype),
        "w3": dense_init(ks[2], (E, D, F), dtype),
        "w2": dense_init(ks[3], (E, F, D), dtype),
    }
    if m.num_shared_experts:
        Fs = F * m.num_shared_experts
        k1, k2, k3 = jax.random.split(ks[4], 3)
        p["ws1"] = dense_init(k1, (D, Fs), dtype)
        p["ws3"] = dense_init(k2, (D, Fs), dtype)
        p["ws2"] = dense_init(k3, (Fs, D), dtype)
    return p


def moe_ffn(p, x, cfg):
    """x: (B, S, D) -> (out, aux_loss)."""
    m = cfg.moe
    B, S, D = x.shape
    E, k = m.num_experts, m.top_k
    N = B * S
    xt = x.reshape(N, D)
    logits = (xt.astype(jnp.float32) @ p["router"])            # (N, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, gate_idx = jax.lax.top_k(probs, k)              # (N, k)
    gate_vals = gate_vals / jnp.sum(gate_vals, axis=-1, keepdims=True)

    # ---- capacity dispatch (position within each expert's buffer)
    cap = int(m.capacity_factor * N * k / E)
    cap = max(cap, 1)
    onehot = jax.nn.one_hot(gate_idx, E, dtype=jnp.int32)      # (N, k, E)
    flat = onehot.reshape(N * k, E)
    pos_in_expert = jnp.cumsum(flat, axis=0) * flat - 1        # (N*k, E)
    pos = jnp.max(pos_in_expert, axis=-1).reshape(N, k)        # (N, k)
    expert = gate_idx
    keep = pos < cap                                           # token dropping
    gate_vals = gate_vals * keep

    # dispatch (N, k) slots -> (E, cap, D) via scatter
    flat_idx = expert * cap + jnp.minimum(pos, cap - 1)        # (N, k)
    buf = jnp.zeros((E * cap, D), x.dtype)
    src = jnp.repeat(xt[:, None, :], k, axis=1)                # (N, k, D)
    buf = buf.at[flat_idx.reshape(-1)].add(
        (src * keep[..., None]).reshape(N * k, D))
    buf = buf.reshape(E, cap, D)

    # expert computation — active FLOPs only
    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, p["w1"])) \
        * jnp.einsum("ecd,edf->ecf", buf, p["w3"])
    out_buf = jnp.einsum("ecf,efd->ecd", h, p["w2"]).reshape(E * cap, D)

    # combine
    gathered = out_buf[flat_idx.reshape(-1)].reshape(N, k, D)
    out = jnp.sum(gathered * gate_vals[..., None].astype(x.dtype), axis=1)

    # shared experts (DeepSeek-style) always active
    if "ws1" in p:
        h = jax.nn.silu(xt @ p["ws1"]) * (xt @ p["ws3"])
        out = out + h @ p["ws2"]

    # GShard load-balance aux loss
    me = jnp.mean(probs, axis=0)                               # (E,)
    ce = jnp.mean(jax.nn.one_hot(gate_idx[:, 0], E, dtype=jnp.float32), axis=0)
    aux = E * jnp.sum(me * ce) * m.aux_loss_weight
    return out.reshape(B, S, D), aux
