"""Unified decoder-only model covering dense GQA / MoE / MLA / RWKV6 /
Mamba-hybrid / VLM-prefix architectures.

Layers are organized into *stages*: a stage is a repeating pattern of
(mixer, ffn) layer specs scanned over its repeat count with stacked params —
jax.lax.scan keeps the HLO size O(pattern) instead of O(n_layers), which is
what makes 64-72 layer 100-400B configs compile quickly in the dry-run.
Heterogeneous architectures (jamba's 1:7 attn:mamba interleave with
alternating MoE) become a pattern of length 8 scanned 9 times.
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro.models import attention, mla, moe, ssm_mamba, ssm_rwkv
from repro.models.common import (dense_init, dtype_of, embed_init, rms_norm,
                                 softmax_cross_entropy, swiglu)

# ------------------------------------------------------------------- stages

def stages(cfg) -> list[tuple[tuple[tuple[str, str], ...], int]]:
    """Returns [(pattern, count)] with pattern = ((mixer, ffn), ...)."""
    L = cfg.n_layers
    if cfg.arch_type == "ssm":                      # rwkv6
        return [((("rwkv", "rwkv_ffn"),), L)]
    if cfg.arch_type == "hybrid":                   # jamba: 1:7, alt MoE
        n = cfg.ssm.attn_every_n
        assert L % n == 0
        pattern = []
        for i in range(n):
            mixer = "attn" if i == 0 else "mamba"
            ffn = "moe" if (cfg.moe is not None and i % 2 == 1) else "dense"
            pattern.append((mixer, ffn))
        return [(tuple(pattern), L // n)]
    if cfg.mla is not None:                         # deepseek: first dense FFN
        return [((("mla", "dense"),), 1), ((("mla", "moe"),), L - 1)]
    if cfg.moe is not None:                         # mixtral
        return [((("attn", "moe"),), L)]
    return [((("attn", "dense"),), L)]              # dense / vlm


# ------------------------------------------------------------------- params

def _init_ffn(key, cfg, dtype):
    k1, k2, k3 = jax.random.split(key, 3)
    D, F = cfg.d_model, cfg.d_ff
    return {"w1": dense_init(k1, (D, F), dtype),
            "w3": dense_init(k2, (D, F), dtype),
            "w2": dense_init(k3, (F, D), dtype)}


def _init_layer(key, spec, cfg, dtype):
    mixer, ffn = spec
    km, kf = jax.random.split(key)
    p: dict[str, Any] = {"ln1": jnp.ones((cfg.d_model,), dtype),
                         "ln2": jnp.ones((cfg.d_model,), dtype)}
    if mixer == "attn":
        p["attn"] = attention.init_attn(km, cfg, dtype)
    elif mixer == "mla":
        p["mla"] = mla.init_mla(km, cfg, dtype)
    elif mixer == "rwkv":
        p["rwkv"] = ssm_rwkv.init_rwkv_mix(km, cfg, dtype)
    elif mixer == "mamba":
        p["mamba"] = ssm_mamba.init_mamba(km, cfg, dtype)
    if ffn == "dense":
        p["ffn"] = _init_ffn(kf, cfg, dtype)
    elif ffn == "moe":
        p["moe"] = moe.init_moe(kf, cfg, dtype)
    elif ffn == "rwkv_ffn":
        p["ffn"] = ssm_rwkv.init_rwkv_ffn(kf, cfg, dtype)
    return p


def _init_superblock(key, pattern, cfg, dtype):
    keys = jax.random.split(key, len(pattern))
    return {f"l{i}": _init_layer(keys[i], spec, cfg, dtype)
            for i, spec in enumerate(pattern)}


def init_params(cfg, key):
    dtype = dtype_of(cfg)
    ks = jax.random.split(key, 4 + len(stages(cfg)))
    V = cfg.vocab_padded
    params: dict[str, Any] = {
        "embed": embed_init(ks[0], (V, cfg.d_model), dtype),
        "final_norm": jnp.ones((cfg.d_model,), dtype),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = dense_init(ks[1], (cfg.d_model, V), dtype)
    if cfg.n_prefix_patches:
        params["patch_proj"] = dense_init(
            ks[2], (cfg.d_model, cfg.d_model), dtype)
    for si, (pattern, count) in enumerate(stages(cfg)):
        keys = jax.random.split(ks[3 + si], count)
        params[f"stage{si}"] = jax.vmap(
            lambda k: _init_superblock(k, pattern, cfg, dtype))(keys)
    return params


def abstract_params(cfg, policy_fn=None):
    """ShapeDtypeStruct param tree (no allocation) for the dry-run."""
    return jax.eval_shape(functools.partial(init_params, cfg),
                          jax.random.PRNGKey(0))


# ------------------------------------------------------------------- caches

def _init_layer_cache(spec, cfg, batch, max_seq, dtype, window):
    mixer, _ = spec
    if mixer == "attn":
        return {"attn": attention.init_cache(cfg, batch, max_seq, dtype, window)}
    if mixer == "mla":
        return {"mla": mla.init_mla_cache(cfg, batch, max_seq, dtype, window)}
    if mixer == "rwkv":
        return {"rwkv": ssm_rwkv.init_rwkv_state(cfg, batch, dtype)}
    if mixer == "mamba":
        return {"mamba": ssm_mamba.init_mamba_state(cfg, batch, dtype)}
    return {}


def init_cache(cfg, batch: int, max_seq: int, window: int = 0):
    dtype = dtype_of(cfg)
    cache = {}
    for si, (pattern, count) in enumerate(stages(cfg)):
        one = {f"l{i}": _init_layer_cache(spec, cfg, batch, max_seq, dtype,
                                          window)
               for i, spec in enumerate(pattern)}
        cache[f"stage{si}"] = jax.tree_util.tree_map(
            lambda a: jnp.zeros((count,) + a.shape, a.dtype)
            if a.dtype != jnp.int32
            else jnp.broadcast_to(a, (count,) + a.shape).copy(), one)
    return cache


# ------------------------------------------------------------------- layers

def _layer_apply(spec, p, x, cfg, mode, positions=None, pos=None,
                 cache=None, window=0, chunked=True):
    """One (mixer, ffn) layer.  Returns (x, new_cache, aux)."""
    mixer, ffn = spec
    aux = jnp.zeros((), jnp.float32)
    h = rms_norm(x, p["ln1"], cfg.norm_eps)
    new_cache = {}
    if mixer == "attn":
        if mode == "train":
            out = attention.attn_train(p["attn"], h, positions, cfg, window)
        elif mode == "prefill":
            out, c = attention.attn_prefill(p["attn"], h, positions, cfg,
                                            cache["attn"], window)
            new_cache["attn"] = c
        else:
            out, c = attention.attn_decode(p["attn"], h, pos, cfg,
                                           cache["attn"], window)
            new_cache["attn"] = c
    elif mixer == "mla":
        if mode == "train":
            out = mla.mla_train(p["mla"], h, positions, cfg, window)
        elif mode == "prefill":
            out, c = mla.mla_prefill(p["mla"], h, positions, cfg,
                                     cache["mla"], window)
            new_cache["mla"] = c
        else:
            out, c = mla.mla_decode(p["mla"], h, pos, cfg, cache["mla"], window)
            new_cache["mla"] = c
    elif mixer == "rwkv":
        st = cache["rwkv"] if cache else ssm_rwkv.init_rwkv_state(
            cfg, x.shape[0], x.dtype)
        out, (x_last, wkv) = ssm_rwkv.rwkv_mix_train(
            p["rwkv"], h, st["x_prev_mix"], st["wkv"], cfg,
            chunked=(mode == "train" or mode == "prefill") and chunked)
        new_cache["rwkv"] = {"x_prev_mix": x_last, "wkv": wkv,
                             "x_prev_ffn": st["x_prev_ffn"]}
    elif mixer == "mamba":
        st = cache["mamba"] if cache else ssm_mamba.init_mamba_state(
            cfg, x.shape[0], x.dtype)
        out, st2 = ssm_mamba.mamba_block(p["mamba"], h, st, cfg,
                                         chunked=chunked and mode != "decode")
        new_cache["mamba"] = st2
    x = x + out

    h = rms_norm(x, p["ln2"], cfg.norm_eps)
    if ffn == "dense":
        x = x + swiglu(h, p["ffn"]["w1"], p["ffn"]["w3"], p["ffn"]["w2"])
    elif ffn == "moe":
        out, aux = moe.moe_ffn(p["moe"], h, cfg)
        x = x + out
    elif ffn == "rwkv_ffn":
        st = new_cache.get("rwkv") or (cache["rwkv"] if cache else
                                       ssm_rwkv.init_rwkv_state(cfg, x.shape[0], x.dtype))
        out, x_last = ssm_rwkv.rwkv_ffn(p["ffn"], h, st["x_prev_ffn"], cfg)
        if "rwkv" in new_cache:
            new_cache["rwkv"]["x_prev_ffn"] = x_last
        x = x + out
    return x, new_cache, aux


def _run_stages(cfg, params, x, mode, positions=None, pos=None, cache=None,
                window=0, remat=False, chunked=True):
    """Scan over every stage.  Returns (x, new_cache, total_aux)."""
    total_aux = jnp.zeros((), jnp.float32)
    new_cache = {}
    for si, (pattern, count) in enumerate(stages(cfg)):
        sp = params[f"stage{si}"]
        sc = cache[f"stage{si}"] if cache is not None else None

        def body(carry, xs):
            x, aux = carry
            layer_p, layer_c = xs
            lc_out = {}
            for i, spec in enumerate(pattern):
                x, c, a = _layer_apply(
                    spec, layer_p[f"l{i}"], x, cfg, mode,
                    positions=positions, pos=pos,
                    cache=None if layer_c is None else layer_c[f"l{i}"],
                    window=window, chunked=chunked)
                lc_out[f"l{i}"] = c
                aux = aux + a
            return (x, aux), lc_out

        body_fn = jax.checkpoint(body) if remat else body
        if sc is None:
            # scan needs a pytree for xs; pass params only
            def body_np(carry, layer_p):
                return body_fn(carry, (layer_p, None))
            (x, total_aux), _ = jax.lax.scan(body_np, (x, total_aux), sp)
        else:
            (x, total_aux), cache_out = jax.lax.scan(
                body_fn, (x, total_aux), (sp, sc))
            new_cache[f"stage{si}"] = cache_out
    return x, new_cache, total_aux


# ------------------------------------------------------------------- embeds

def _embed_tokens(cfg, params, tokens):
    return jnp.take(params["embed"], tokens, axis=0)


def _inputs_embeds(cfg, params, batch):
    """Token embeddings, with VLM patch prefix when configured."""
    emb = _embed_tokens(cfg, params, batch["tokens"])
    if cfg.n_prefix_patches:
        patches = batch["patch_embeds"].astype(emb.dtype) @ params["patch_proj"]
        emb = jnp.concatenate([patches, emb], axis=1)
    return emb


def _logits(cfg, params, x):
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = x @ head
    if cfg.vocab_padded != cfg.vocab:
        ids = jax.lax.broadcasted_iota(jnp.int32, logits.shape,
                                       logits.ndim - 1)
        logits = jnp.where(ids < cfg.vocab, logits,
                           jnp.asarray(-1e9, logits.dtype))
    return logits


# ------------------------------------------------------------------- public

def loss_fn(cfg, params, batch, window: int = 0, remat: bool = True,
            chunked: bool = True):
    """batch: tokens (B,S), labels (B,S) [, patch_embeds (B,P,D)].

    Labels are next-token targets aligned with the *token* positions;
    label -100 masks a position out.
    """
    x = _inputs_embeds(cfg, params, batch)
    S = x.shape[1]
    positions = jnp.arange(S, dtype=jnp.int32)
    x, _, aux = _run_stages(cfg, params, x, "train", positions=positions,
                            window=window, remat=remat, chunked=chunked)
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    if cfg.n_prefix_patches:
        x = x[:, cfg.n_prefix_patches:, :]
    logits = _logits(cfg, params, x)
    labels = batch["labels"]
    mask = (labels >= 0).astype(jnp.float32)
    loss = softmax_cross_entropy(logits, jnp.maximum(labels, 0), mask)
    return loss + aux


def hidden_states(cfg, params, batch, window: int = 0, chunked: bool = True):
    """Final-norm hidden states (B, S, D) — feature extractor for the
    CodedFedL coded linear-probe head (core/coded_probe.py)."""
    x = _inputs_embeds(cfg, params, batch)
    S = x.shape[1]
    positions = jnp.arange(S, dtype=jnp.int32)
    x, _, _ = _run_stages(cfg, params, x, "train", positions=positions,
                          window=window, remat=False, chunked=chunked)
    return rms_norm(x, params["final_norm"], cfg.norm_eps)


def prefill(cfg, params, batch, window: int = 0, chunked: bool = True):
    """Returns (last-position logits (B, V), cache)."""
    x = _inputs_embeds(cfg, params, batch)
    B, S = x.shape[0], x.shape[1]
    positions = jnp.arange(S, dtype=jnp.int32)
    cache = init_cache(cfg, B, S, window)
    x, cache, _ = _run_stages(cfg, params, x, "prefill", positions=positions,
                              cache=cache, window=window, chunked=chunked)
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    return _logits(cfg, params, x[:, -1, :]), cache


def decode_step(cfg, params, cache, tokens, pos, window: int = 0):
    """One-token decode.  tokens: (B, 1); pos: scalar int32.

    Returns (logits (B, V), new_cache)."""
    x = _embed_tokens(cfg, params, tokens)
    x, cache, _ = _run_stages(cfg, params, x, "decode", pos=pos, cache=cache,
                              window=window)
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    return _logits(cfg, params, x[:, -1, :]), cache
