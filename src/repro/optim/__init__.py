"""Optimizers: SGD (+momentum), Adam, LR schedules."""
from repro.optim import optimizers, schedule

__all__ = ["optimizers", "schedule"]
