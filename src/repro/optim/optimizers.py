"""Minimal functional optimizers (no external deps).

Each optimizer is (init_fn, update_fn):
  state = init(params)
  params, state = update(params, grads, state, lr)
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def sgd():
    def init(params):
        return ()

    def update(params, grads, state, lr):
        new = jax.tree_util.tree_map(
            lambda p, g: (p - lr * g.astype(p.dtype)).astype(p.dtype),
            params, grads)
        return new, state

    return init, update


def momentum(beta: float = 0.9):
    def init(params):
        return jax.tree_util.tree_map(
            lambda p: jnp.zeros_like(p, jnp.float32), params)

    def update(params, grads, state, lr):
        new_state = jax.tree_util.tree_map(
            lambda m, g: beta * m + g.astype(jnp.float32), state, grads)
        new = jax.tree_util.tree_map(
            lambda p, m: (p - lr * m).astype(p.dtype), params, new_state)
        return new, new_state

    return init, update


def adam(b1: float = 0.9, b2: float = 0.999, eps: float = 1e-8):
    def init(params):
        z = lambda p: jnp.zeros_like(p, jnp.float32)
        return {"m": jax.tree_util.tree_map(z, params),
                "v": jax.tree_util.tree_map(z, params),
                "t": jnp.zeros((), jnp.int32)}

    def update(params, grads, state, lr):
        t = state["t"] + 1
        m = jax.tree_util.tree_map(
            lambda m_, g: b1 * m_ + (1 - b1) * g.astype(jnp.float32),
            state["m"], grads)
        v = jax.tree_util.tree_map(
            lambda v_, g: b2 * v_ + (1 - b2) * jnp.square(g.astype(jnp.float32)),
            state["v"], grads)
        bc1 = 1 - b1 ** t.astype(jnp.float32)
        bc2 = 1 - b2 ** t.astype(jnp.float32)
        new = jax.tree_util.tree_map(
            lambda p, m_, v_: (p - lr * (m_ / bc1)
                               / (jnp.sqrt(v_ / bc2) + eps)).astype(p.dtype),
            params, m, v)
        return new, {"m": m, "v": v, "t": t}

    return init, update


def get(name: str):
    if name == "sgd":
        return sgd()
    if name == "momentum":
        return momentum()
    if name == "adam":
        return adam()
    raise ValueError(name)
