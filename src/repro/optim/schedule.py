"""Learning-rate schedules."""
from __future__ import annotations


def step_decay(base_lr: float, decay: float, milestones):
    """Paper §V-A: step decay at given epochs."""
    def lr(epoch: int) -> float:
        out = base_lr
        for m in milestones:
            if epoch >= m:
                out *= decay
        return out
    return lr


def cosine(base_lr: float, total_steps: int, warmup: int = 0,
           min_frac: float = 0.1):
    import math

    def lr(step: int) -> float:
        if warmup and step < warmup:
            return base_lr * (step + 1) / warmup
        t = (step - warmup) / max(total_steps - warmup, 1)
        t = min(max(t, 0.0), 1.0)
        return base_lr * (min_frac + (1 - min_frac) * 0.5 * (1 + math.cos(math.pi * t)))
    return lr
