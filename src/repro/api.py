"""Declarative experiment API: one entrypoint for every workload shape.

The paper's experiments are a handful of knobs — scheme, coding
redundancy, load allocation, delay profile, backends.  `ExperimentSpec`
(repro.config) freezes those knobs into one JSON-serializable value, the
scheme registry (repro.core.schemes) makes the straggler-mitigation
strategy pluggable, and `build_experiment` turns spec + data into a
runnable `Experiment` whose ``.run`` / ``.run_multi`` / ``.sweep`` all
flow through the shared compiled-step machinery
(`fed_runtime.build_consts` / `fed_runtime.build_step`).

    from repro.api import ExperimentSpec, build_experiment
    from repro.config import FLConfig, TrainConfig

    spec = ExperimentSpec(
        fl=FLConfig(n_clients=12, delta=0.2),
        train=TrainConfig(learning_rate=0.5),
        scheme="partial_coded",
        scheme_params={"u_fraction": 0.3},
        delay_profile="paper",
        kernel_backend="pallas",
    )
    exp = build_experiment(spec, xs, ys)
    result = exp.run(100)

    # specs round-trip through JSON for logging / artifact provenance
    same = ExperimentSpec.from_dict(json.loads(json.dumps(spec.to_dict())))
    assert same == spec
"""
from __future__ import annotations

from typing import Optional

import numpy as np

from repro.config import ExperimentSpec
from repro.core import schemes
from repro.core.fed_runtime import (Experiment, FedResult,  # noqa: F401
                                    MultiFedResult, RoundLog, RunHealth)
from repro.core.run_state import RunState  # noqa: F401
from repro.core.schemes import (Scheme, get_scheme, grid_names,  # noqa: F401
                                register, registered_names)
from repro.faults import (FAULT_PROFILES, FaultProfile,  # noqa: F401
                          get_fault_profile)
from repro.net.channel import (CHANNEL_PROFILES,  # noqa: F401
                               ChannelProfile)
from repro.obs import (Attribution, RunJournal,  # noqa: F401
                       histories_equal, history_from_journal, load_events)
from repro.obs import spans as obs_spans  # noqa: F401

__all__ = [
    "ExperimentSpec", "Experiment", "ExperimentService", "FedResult",
    "MultiFedResult", "RoundLog", "RunHealth", "RunState", "Scheme",
    "build_experiment", "get_scheme", "grid_names", "register",
    "registered_names", "CHANNEL_PROFILES", "ChannelProfile",
    "FAULT_PROFILES", "FaultProfile", "get_fault_profile",
    "Attribution", "RunJournal", "load_events", "history_from_journal",
    "histories_equal", "obs_spans",
]


def __getattr__(name):
    # lazy: launch.service imports build_experiment from here, so a
    # top-level import would be circular
    if name == "ExperimentService":
        from repro.launch.service import ExperimentService
        return ExperimentService
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")


def build_experiment(spec: "ExperimentSpec | dict", x_stack=None,
                     y_stack=None, *,
                     nodes: Optional[list] = None,
                     rng: Optional[np.random.Generator] = None,
                     mesh=None, data_fn=None):
    """Build a runnable `Experiment` from a spec and client data.

    spec: an `ExperimentSpec` (or its `to_dict()` form, revived here);
    x_stack: (n, l, q) RFF-embedded client features — or, with
    ``spec.fused_embed=True``, the RAW (n, l, d) features (the embedding
    then happens inside the per-round gradient kernel, parameterized by
    ``spec.rff``); y_stack: (n, l, c) targets.  `nodes` / `rng` override the delay network and the host RNG
    (both default to the spec's seeds, so equal specs reproduce equal
    deployments).  `mesh` accepts a concrete 1-D "clients"
    `jax.sharding.Mesh` (not serializable, hence not a spec field) or a
    device count, overriding ``spec.mesh``.

    Specs with ``hier_shards > 1`` or ``sample_fraction < 1.0`` build a
    `repro.hier.HierExperiment` instead (edge-aggregator shards, sampled
    cohorts with coded compensation); those may stream client blocks via
    ``data_fn(lo, hi) -> (x, y)`` in place of dense stacks, so a
    population of 1e5-1e6 clients never materializes an (n, l, q)
    tensor.  The identity configuration (``hier_shards=1,
    sample_fraction=1.0``) always takes the flat engine, so its
    trajectories are bit-identical to the pre-hier runtime.
    """
    if isinstance(spec, dict):
        spec = ExperimentSpec.from_dict(spec)
    # validate the scheme against the live registry up front so the error
    # points at the spec, not at a stack frame deep in Experiment setup
    schemes.get_scheme(spec.resolved_scheme)
    if spec.hier_active:
        from repro.hier import HierExperiment
        if nodes is not None or mesh is not None:
            raise ValueError(
                "the hierarchical tier builds its delay population from "
                "the spec (repro.hier.population_delay_arrays) and shards "
                "clients over edge aggregators; nodes/mesh overrides are "
                "not supported with hier_shards > 1 or "
                "sample_fraction < 1.0")
        return HierExperiment(spec, x_stack, y_stack, data_fn=data_fn,
                              rng=rng)
    if data_fn is not None:
        raise ValueError(
            "data_fn streaming is only supported by the hierarchical tier "
            "(hier_shards > 1 or sample_fraction < 1.0); the flat engine "
            "takes dense x_stack/y_stack")
    return Experiment(spec, x_stack, y_stack, nodes=nodes, rng=rng,
                      mesh=mesh)
