"""Pallas TPU kernel: fused local parity encoding (paper eq. 19).

    parity = G @ diag(w) @ X       G: (u, l), w: (l,), X: (l, q)

Each client runs this once over its (transformed) local dataset to produce
its parity set.  The diagonal weighting is fused into the generator tile in
VMEM (G_tile * w_tile) so diag(w) @ X is never materialized.  Grid
(U/bu, Q/bq, L/bl) with the contraction dim innermost; the output block
accumulates across L steps.

`parity_encode_batched` is the all-clients variant the federated runtime's
coded setup feeds: the client axis becomes the outermost grid dimension
(like `linreg_grad_masked`), so all n local parity sets come from ONE tiled
kernel launch instead of a per-client Python loop.  Block-for-block it runs
the same dots in the same order as n single-client calls, so the two are
bit-identical.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(g_ref, w_ref, x_ref, o_ref, *, nk: int):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    gw = g_ref[...] * w_ref[...]                 # (bu, bl) * (1, bl)
    o_ref[...] += jnp.dot(gw, x_ref[...], preferred_element_type=o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bu", "bq", "bl", "interpret"))
def parity_encode(g, w, x, *, bu: int = 128, bq: int = 128, bl: int = 128,
                  interpret: bool = True):
    """(u, l), (l,), (l, q) -> (u, q).  Requires block divisibility."""
    u, l = g.shape
    l2, q = x.shape
    assert l == l2 and w.shape == (l,)
    assert u % bu == 0 and q % bq == 0 and l % bl == 0, (u, l, q, bu, bq, bl)
    nk = l // bl
    w2 = w.reshape(1, l)
    return pl.pallas_call(
        functools.partial(_kernel, nk=nk),
        grid=(u // bu, q // bq, nk),
        in_specs=[
            pl.BlockSpec((bu, bl), lambda i, j, k: (i, k)),
            pl.BlockSpec((1, bl), lambda i, j, k: (0, k)),
            pl.BlockSpec((bl, bq), lambda i, j, k: (k, j)),
        ],
        out_specs=pl.BlockSpec((bu, bq), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((u, q), g.dtype),
        interpret=interpret,
    )(g, w2, x)


def _batched_kernel(g_ref, w_ref, x_ref, o_ref):
    k = pl.program_id(3)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    gw = g_ref[0] * w_ref[0]                     # (bu, bl) * (1, bl)
    o_ref[...] += jnp.dot(gw, x_ref[0],
                          preferred_element_type=o_ref.dtype)[None]


@functools.partial(jax.jit, static_argnames=("bu", "bq", "bl", "interpret"))
def parity_encode_batched(g, w, x, *, bu: int = 128, bq: int = 128,
                          bl: int = 128, interpret: bool = True):
    """All-clients parity encode: (n, u, l), (n, l), (n, l, q) -> (n, u, q).

    Grid (n, U/bu, Q/bq, L/bl): the client axis is outermost, so the whole
    population's parity sets come from one kernel launch.  Requires block
    divisibility on u/q/l (ops.parity_encode_batched pads).
    """
    n, u, l = g.shape
    n2, l2, q = x.shape
    assert n == n2 and l == l2 and w.shape == (n, l)
    assert u % bu == 0 and q % bq == 0 and l % bl == 0, (u, l, q, bu, bq, bl)
    w3 = w.reshape(n, 1, l)
    return pl.pallas_call(
        _batched_kernel,
        grid=(n, u // bu, q // bq, l // bl),
        in_specs=[
            pl.BlockSpec((1, bu, bl), lambda b, i, j, k: (b, i, k)),
            pl.BlockSpec((1, 1, bl), lambda b, i, j, k: (b, 0, k)),
            pl.BlockSpec((1, bl, bq), lambda b, i, j, k: (b, k, j)),
        ],
        out_specs=pl.BlockSpec((1, bu, bq), lambda b, i, j, k: (b, i, j)),
        out_shape=jax.ShapeDtypeStruct((n, u, q), g.dtype),
        interpret=interpret,
    )(g, w3, x)
