"""Pure-jnp oracles for every Pallas kernel in this package.

These are the semantic ground truth: each kernel's test sweeps shapes and
dtypes and asserts allclose against the function of the same name here.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def rff_embed(x, omega, delta):
    """Random Fourier feature map (paper eq. 18).

    x: (m, d), omega: (d, q), delta: (q,) -> (m, q)
      phi(x) = sqrt(2/q) * cos(x @ omega + delta)
    """
    q = omega.shape[1]
    return jnp.sqrt(2.0 / q) * jnp.cos(x @ omega + delta[None, :])


def linreg_grad(x, theta, y):
    """Unnormalized squared-loss linear-regression gradient (paper eq. 7/10).

    x: (m, q), theta: (q, c), y: (m, c) -> (q, c)
      g = x^T (x @ theta - y)
    Callers divide by the load l (or u) themselves.
    """
    return x.T @ (x @ theta - y)


def linreg_grad_masked(x, theta, y, mask):
    """Row-masked gradient (batched-engine form of eq. 7/10).

    x: (l, q), theta: (q, c), y: (l, c), mask: (l,) per-row weights ->
      g = x^T diag(mask) (x @ theta - y)
    Rows with mask 0 contribute exactly zero, so callers may hand over
    mask-padded dense subsets without pre-zeroing the padding; fractional
    entries scale a row's gradient (the fused coded round's 1/u factor).
    """
    r = (x @ theta - y) * mask[:, None].astype(x.dtype)
    return x.T @ r


def parity_encode(g, w, x):
    """Local parity dataset encoding (paper eq. 19).

    g: (u, l) generator, w: (l,) diagonal weights, x: (l, q) data -> (u, q)
      parity = G @ diag(w) @ X
    """
    return (g * w[None, :]) @ x


def gqa_decode(q, k, v, k_pos, q_pos, window: int = 0):
    """One-token GQA decode attention oracle.

    q: (B, H, hd); k/v: (B, T, K, hd/hd_v); k_pos: (T,); q_pos: ().
    """
    import numpy as np
    B, H, hd = q.shape
    T, K = k.shape[1], k.shape[2]
    G = H // K
    qr = q.reshape(B, K, G, hd).astype(jnp.float32) / np.sqrt(hd)
    s = jnp.einsum("bkgd,btkd->bkgt", qr, k.astype(jnp.float32))
    valid = (k_pos >= 0) & (k_pos <= q_pos)
    if window > 0:
        valid = valid & (k_pos > q_pos - window)
    s = jnp.where(valid[None, None, None, :], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgt,btkd->bkgd", p, v.astype(jnp.float32))
    return out.reshape(B, H, v.shape[-1]).astype(q.dtype)
