"""Pallas TPU kernel: flash-decode GQA attention (one token vs KV cache).

§Perf iteration 2 made single-token decode attention the framework's
serving hot-spot expression (one-shot einsum + masked softmax); this kernel
is its TPU-native tiling: the cache streams through VMEM in (bt, K, hd)
chunks with an online-softmax accumulator in VMEM scratch, so the (T,)-long
score row never materializes in HBM.  Grid (B, T/bt), sequential on the
chunk axis; the accumulator re-initializes at chunk 0 and the output block
is written at the final chunk.

Masking: slot positions `k_pos` (rolling caches store -1 for empty slots)
must be <= q_pos and, for sliding-window decode, > q_pos - window.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, kpos_ref, qpos_ref, o_ref,
            m_ref, l_ref, acc_ref, *, nt: int, G: int, window: int,
            scale: float):
    t = pl.program_id(1)

    @pl.when(t == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0].astype(jnp.float32) * scale          # (H, hd)
    k = k_ref[0].astype(jnp.float32)                  # (bt, K, hd)
    v = v_ref[0].astype(jnp.float32)                  # (bt, K, hd_v)
    K = k.shape[1]
    qg = q.reshape(K, G, q.shape[-1])
    s = jnp.einsum("kgd,tkd->kgt", qg, k)             # (K, G, bt)
    kp = kpos_ref[0]                                  # (bt,)
    qp = qpos_ref[0, 0]
    valid = (kp >= 0) & (kp <= qp)
    if window > 0:
        valid = valid & (kp > qp - window)
    s = jnp.where(valid[None, None, :], s, NEG_INF)

    m_prev = m_ref[...]                               # (K, G)
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
    p = jnp.exp(s - m_new[..., None])
    corr = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=-1)
    acc_ref[...] = acc_ref[...] * corr[..., None] + jnp.einsum(
        "kgt,tkd->kgd", p, v)
    m_ref[...] = m_new

    @pl.when(t == nt - 1)
    def _finalize():
        out = acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)[..., None]
        o_ref[0] = out.reshape(o_ref.shape[1:]).astype(o_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("bt", "window", "interpret"))
def gqa_decode(q, k, v, k_pos, q_pos, *, bt: int = 512, window: int = 0,
               interpret: bool = True):
    """q: (B, H, hd); k: (B, T, K, hd); v: (B, T, K, hd_v);
    k_pos: (T,) int32 slot positions; q_pos: () int32.
    Returns (B, H, hd_v).  Requires T % bt == 0 and H % K == 0."""
    B, H, hd = q.shape
    T, K, hd_v = k.shape[1], k.shape[2], v.shape[-1]
    assert T % bt == 0, (T, bt)
    G = H // K
    nt = T // bt
    kpos2 = jnp.broadcast_to(k_pos.reshape(1, T), (1, T))
    qpos2 = jnp.reshape(q_pos.astype(jnp.int32), (1, 1))
    scale = 1.0 / (hd ** 0.5)
    return pl.pallas_call(
        functools.partial(_kernel, nt=nt, G=G, window=window, scale=scale),
        grid=(B, nt),
        in_specs=[
            pl.BlockSpec((1, H, hd), lambda b, t: (b, 0, 0)),
            pl.BlockSpec((1, bt, K, hd), lambda b, t: (b, t, 0, 0)),
            pl.BlockSpec((1, bt, K, hd_v), lambda b, t: (b, t, 0, 0)),
            pl.BlockSpec((1, bt), lambda b, t: (0, t)),
            pl.BlockSpec((1, 1), lambda b, t: (0, 0)),
        ],
        out_specs=pl.BlockSpec((1, H, hd_v), lambda b, t: (b, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, hd_v), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((K, G), jnp.float32),
            pltpu.VMEM((K, G), jnp.float32),
            pltpu.VMEM((K, G, hd_v), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v, kpos2, qpos2)
