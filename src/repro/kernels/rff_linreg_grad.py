"""Pallas TPU kernel: fused RFF-embed -> masked linear-regression gradient.

    phi_b   = sqrt(2/q) * cos(X_b @ Omega + delta)          (paper eq. 18)
    g_b     = phi_b^T diag(mask_b) (phi_b @ theta - Y_b)    (paper eq. 7/10)

This fuses the two passes the round path used to launch separately
(`rff_embed` then `linreg_grad_masked`): the RAW client features
(rows, L, d) stay resident in HBM and the embedded (rows, L, q) tensor is
never materialized there — each (bm, q) row-block of phi is computed
in-kernel into VMEM scratch, consumed for the residual and the q-block
transposed accumulations, and discarded.

Grid (rows, L/bm, Q/bq) with the q-block axis innermost, mirroring
`linreg_grad_masked`: at j == 0 the kernel embeds the (bm, d) raw row
block against the resident Omega (one MXU contraction over the full d
axis — Mosaic tiles the K loop internally), adds delta, applies the
cos + sqrt(2/q) finalization, and forms the masked residual
R = phi @ theta - Y in scratch; each j-step then accumulates
phi[:, j-block]^T @ R into the revisited (q, c) output block.

The coded parity pseudo-client row rides along in the SAME grid: parity
rows live in embedded q-space already (they are generator-weighted sums
of embedded points), so a pre-embedded (L, q) `pphi` input substitutes
for the in-kernel embed on grid rows b >= n_real.  Its mask entries carry
the 1/(u (1-pnr_C)) coded-gradient scale exactly as in the two-pass fused
layout, so one launch still produces the whole round's gradients.

Dtypes: with float32 inputs everything runs in f32.  With bfloat16
inputs (x/omega/delta/theta/y), the embed matmul, cosine, residual and
output accumulate in float32 (`preferred_element_type`) and the output
is float32 in both variants — the bf16 variant halves the streamed-input
HBM traffic without giving up gradient accumulation precision.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu

from repro.kernels.linreg_grad import _VMEM_BUDGET_BYTES

_ACC = jnp.float32


def _check_fused_vmem(d: int, q: int, c: int, bm: int, bq: int,
                      in_dtype) -> None:
    """Clear error when the resident working set cannot fit VMEM.

    Omega (d, q) and theta (q, c) are resident across the whole grid; the
    phi row-block scratch (bm, q) and residual (bm, c) are f32; the raw
    row block (bm, d), labels (bm, c), parity row block (bm, q) and the
    (bq, c) output tile stream per step.
    """
    in_size = jnp.dtype(in_dtype).itemsize
    acc_size = jnp.dtype(_ACC).itemsize
    nbytes = ((d * q + q * c + bm * d + bm * c + bm * q) * in_size
              + (bm * q + bm * c + bq * c) * acc_size)
    if nbytes > _VMEM_BUDGET_BYTES:
        raise ValueError(
            f"rff_linreg_grad: resident working set for d={d}, q={q}, "
            f"c={c}, bm={bm}, bq={bq} ({jnp.dtype(in_dtype).name} inputs) "
            f"needs ~{nbytes / 2**20:.1f} MiB of VMEM (Omega + theta + phi "
            f"scratch), over the ~{_VMEM_BUDGET_BYTES / 2**20:.0f} MiB "
            "per-core budget. Shrink q/d or fall back to the two-pass "
            "rff_embed + linreg_grad_masked path.")


def _kernel(x_ref, omega_ref, delta_ref, theta_ref, y_ref, mask_ref,
            pphi_ref, o_ref, phi_ref, r_ref, *, n_real: int, q_true: int,
            bq: int):
    b = pl.program_id(0)
    i = pl.program_id(1)
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _embed_and_residual():
        # phi row block for this (client, row-block): embedded on the fly
        # for real clients, read pre-embedded for the parity pseudo-row
        @pl.when(b < n_real)
        def _embed():
            acc = jnp.dot(x_ref[0], omega_ref[...],
                          preferred_element_type=_ACC)
            scale = jnp.array(math.sqrt(2.0 / q_true), _ACC)
            phi_ref[...] = scale * jnp.cos(acc + delta_ref[...].astype(_ACC))

        @pl.when(b >= n_real)
        def _parity():
            phi_ref[...] = pphi_ref[0].astype(_ACC)

        r = (jnp.dot(phi_ref[...], theta_ref[...].astype(_ACC),
                     preferred_element_type=_ACC)
             - y_ref[0].astype(_ACC))
        r_ref[...] = r * mask_ref[0][:, None].astype(_ACC)

    @pl.when(i == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    phi_blk = phi_ref[:, pl.ds(j * bq, bq)]
    o_ref[...] += jnp.dot(phi_blk.T, r_ref[...],
                          preferred_element_type=o_ref.dtype)[None]


@functools.partial(jax.jit, static_argnames=("bm", "bq", "interpret",
                                             "n_real", "q_true"))
def rff_linreg_grad_masked(x_raw, omega, delta, theta, y, mask, pphi, *,
                           n_real: int, bm: int = 128, bq: int = 128,
                           interpret: bool = True,
                           q_true: int | None = None):
    """Fused embed->gradient over a dense padded client axis.

    x_raw: (rows, L, d) raw features (rows beyond n_real are dummies whose
    blocks are fetched but never read), omega: (d, q), delta: (q,),
    theta: (q, c), y: (rows, L, c), mask: (rows, L), pphi: (1, L, q)
    -> (rows, q, c) float32 with

      g_b = phi_b^T diag(mask_b) (phi_b theta - Y_b),
      phi_b = sqrt(2/q_true) cos(X_b omega + delta)   for b <  n_real,
      phi_b = pphi[0]                                 for b >= n_real.

    Requires block divisibility on L/q (ops.rff_linreg_grad_masked pads);
    `q_true` is the unpadded feature count feeding the sqrt(2/q) scale.
    Mask entries are per-row weights (the parity row carries the coded
    1/u scale); rows with mask 0 contribute exactly zero, so padded rows
    need not be pre-zeroed.  Output is float32 for bf16 inputs too (the
    accumulator dtype).
    """
    rows, L, d = x_raw.shape
    d2, q = omega.shape
    q2, c = theta.shape
    assert d == d2 and q == q2 and delta.shape == (q,)
    assert y.shape == (rows, L, c) and mask.shape == (rows, L)
    assert pphi.shape == (1, L, q)
    assert L % bm == 0 and q % bq == 0, (rows, L, q, bm, bq)
    if q_true is None:
        q_true = q
    if q_true <= 0:
        raise ValueError(f"q_true must be positive, got {q_true}")
    if not 0 <= n_real <= rows:
        raise ValueError(f"n_real={n_real} out of range for rows={rows}")
    _check_fused_vmem(d, q, c, bm, bq, x_raw.dtype)
    delta2 = delta.reshape(1, q)
    return pl.pallas_call(
        functools.partial(_kernel, n_real=n_real, q_true=q_true, bq=bq),
        grid=(rows, L // bm, q // bq),
        in_specs=[
            pl.BlockSpec((1, bm, d), lambda b, i, j: (b, i, 0)),   # raw rows
            pl.BlockSpec((d, q), lambda b, i, j: (0, 0)),          # Omega
            pl.BlockSpec((1, q), lambda b, i, j: (0, 0)),          # delta
            pl.BlockSpec((q, c), lambda b, i, j: (0, 0)),          # theta
            pl.BlockSpec((1, bm, c), lambda b, i, j: (b, i, 0)),   # labels
            pl.BlockSpec((1, bm), lambda b, i, j: (b, i)),         # weights
            pl.BlockSpec((1, bm, q), lambda b, i, j: (0, i, 0)),   # parity phi
        ],
        out_specs=pl.BlockSpec((1, bq, c), lambda b, i, j: (b, j, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, q, c), _ACC),
        scratch_shapes=[pltpu.VMEM((bm, q), _ACC),
                        pltpu.VMEM((bm, c), _ACC)],
        interpret=interpret,
    )(x_raw, omega, delta2, theta, y, mask, pphi)
