"""Pallas TPU kernel: fused random-Fourier-feature embedding (paper eq. 18).

    out[i, s] = sqrt(2/q) * cos( sum_k x[i, k] * omega[k, s] + delta[s] )

The matmul runs on the MXU with (bm, bk) x (bk, bq) VMEM tiles; the bias add,
cosine and scale are fused into the final K-step so the (m, q) intermediate
x @ omega never round-trips to HBM.  Grid is (M/bm, Q/bq, D/bk) with the
contraction dimension innermost; the output block is revisited across K steps
and used as the accumulator.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(x_ref, omega_ref, delta_ref, o_ref, *, nk: int, q_true: int):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(x_ref[...], omega_ref[...],
                          preferred_element_type=o_ref.dtype)

    @pl.when(k == nk - 1)
    def _finalize():
        scale = jnp.array(math.sqrt(2.0 / q_true), dtype=o_ref.dtype)
        o_ref[...] = scale * jnp.cos(o_ref[...] + delta_ref[...])


@functools.partial(jax.jit,
                   static_argnames=("bm", "bq", "bk", "interpret", "q_true"))
def rff_embed(x, omega, delta, *, bm: int = 128, bq: int = 128, bk: int = 128,
              interpret: bool = True, q_true: int | None = None):
    """x: (m, d), omega: (d, q), delta: (q,) -> (m, q).  Requires divisibility.

    q_true: the unpadded feature count used in the sqrt(2/q) scale (defaults
    to omega's column count; callers that zero-pad q must pass the original).
    """
    m, d = x.shape
    d2, q = omega.shape
    assert d == d2 and delta.shape == (q,)
    assert m % bm == 0 and q % bq == 0 and d % bk == 0, (m, q, d, bm, bq, bk)
    # explicit None check: `q_true or q` would silently substitute the
    # padded q when a caller passes q_true=0
    if q_true is None:
        q_true = q
    if q_true <= 0:
        raise ValueError(f"q_true must be positive, got {q_true}")
    nk = d // bk
    delta2 = delta.reshape(1, q)
    return pl.pallas_call(
        functools.partial(_kernel, nk=nk, q_true=q_true),
        grid=(m // bm, q // bq, nk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk, bq), lambda i, j, k: (k, j)),
            pl.BlockSpec((1, bq), lambda i, j, k: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bq), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, q), x.dtype),
        interpret=interpret,
    )(x, omega, delta2)
