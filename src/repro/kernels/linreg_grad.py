"""Pallas TPU kernel: fused linear-regression gradient (paper eq. 7/10/28).

    g = X^T (X @ theta - Y)        X: (m, q), theta: (q, c), Y: (m, c)

This is the compute hot-spot of every CodedFedL training round (client
partial gradients AND the server's coded gradient share this form).  The
kernel streams row-blocks of X through VMEM once: for each M-block it forms
the residual R = X_blk @ theta - Y_blk in VMEM scratch, then accumulates
X_blk^T @ R into the (q, c) output without materializing the (m, c) residual
in HBM.  Grid (M/bm, Q/bq); the residual is computed once per M-block (at
j == 0) using a full-q view of the X row-block, and the output accumulates
across M steps (revisited output block).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu


def _kernel(xfull_ref, theta_ref, y_ref, xblk_ref, o_ref, r_ref):
    i = pl.program_id(0)
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _residual():
        r_ref[...] = (jnp.dot(xfull_ref[...], theta_ref[...],
                              preferred_element_type=r_ref.dtype)
                      - y_ref[...])

    @pl.when(i == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(xblk_ref[...].T, r_ref[...],
                          preferred_element_type=o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bm", "bq", "interpret"))
def linreg_grad(x, theta, y, *, bm: int = 128, bq: int = 128,
                interpret: bool = True):
    """g = X^T (X theta - Y): (m, q), (q, c), (m, c) -> (q, c)."""
    m, q = x.shape
    q2, c = theta.shape
    assert q == q2 and y.shape == (m, c)
    assert m % bm == 0 and q % bq == 0, (m, q, bm, bq)
    return pl.pallas_call(
        _kernel,
        grid=(m // bm, q // bq),
        in_specs=[
            pl.BlockSpec((bm, q), lambda i, j: (i, 0)),     # full-q row block
            pl.BlockSpec((q, c), lambda i, j: (0, 0)),      # theta resident
            pl.BlockSpec((bm, c), lambda i, j: (i, 0)),     # labels row block
            pl.BlockSpec((bm, bq), lambda i, j: (i, j)),    # X^T side tile
        ],
        out_specs=pl.BlockSpec((bq, c), lambda i, j: (j, 0)),
        out_shape=jax.ShapeDtypeStruct((q, c), x.dtype),
        scratch_shapes=[pltpu.VMEM((bm, c), x.dtype)],
        interpret=interpret,
    )(x, theta, y, x)
