"""Pallas TPU kernel: fused linear-regression gradient (paper eq. 7/10/28).

    g = X^T (X @ theta - Y)        X: (m, q), theta: (q, c), Y: (m, c)

This is the compute hot-spot of every CodedFedL training round (client
partial gradients AND the server's coded gradient share this form).  The
kernel streams row-blocks of X through VMEM once: for each M-block it forms
the residual R = X_blk @ theta - Y_blk in VMEM scratch, then accumulates
X_blk^T @ R into the (q, c) output without materializing the (m, c) residual
in HBM.  Grid (M/bm, Q/bq); the residual is computed once per M-block (at
j == 0) using a full-q view of the X row-block, and the output accumulates
across M steps (revisited output block).

`linreg_grad_masked` is the batched variant the federated runtime's scan
engine feeds with its dense mask-padded (n, l_max, q) client tensor: the
client axis becomes the outermost grid dimension and the mask is fused
into the residual, so padded rows contribute exactly zero even when the
caller did not pre-zero them.  Mask entries are general per-row *weights*,
not just 0/1 validity bits — the runtime's fused coded round appends the
global parity set as an (n+1)-th pseudo-client row whose mask carries the
coded-gradient 1/(u (1-pnr_C)) scale, so the whole round (client gradients
+ coded gradient) is ONE launch of this kernel.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu

# Per-core VMEM is ~16 MiB on current TPUs; leave headroom for double
# buffering of the streamed input blocks.
_VMEM_BUDGET_BYTES = 16 * 1024 * 1024


def _check_c_fits_vmem(q: int, c: int, bm: int, bq: int, dtype) -> None:
    """Validate that the label width `c` leaves the kernel's resident VMEM
    working set inside the budget.

    theta (q, c), one labels row-block (bm, c), the residual scratch (bm, c)
    and the output tile (bq, c) are all resident per grid step, so a large c
    (or q) blows VMEM with an opaque Mosaic/Pallas shape assert.  Raise a
    clear, actionable error instead.
    """
    itemsize = jnp.dtype(dtype).itemsize
    resident = (bm * q          # full-q X row block (residual operand)
                + q * c         # theta, resident across the whole grid
                + bm * c        # Y row block
                + bm * c        # residual scratch
                + bm * bq       # X^T side tile
                + bq * c)       # output tile
    nbytes = resident * itemsize
    if nbytes > _VMEM_BUDGET_BYTES:
        raise ValueError(
            f"linreg_grad: label width c={c} with q={q}, bm={bm}, bq={bq} "
            f"({jnp.dtype(dtype).name}) needs ~{nbytes / 2**20:.1f} MiB of "
            f"resident VMEM (theta + label/residual/output tiles), over the "
            f"~{_VMEM_BUDGET_BYTES / 2**20:.0f} MiB per-core budget. Split "
            "the label columns into <=128-wide chunks or shrink bm/bq.")


def _kernel(xfull_ref, theta_ref, y_ref, xblk_ref, o_ref, r_ref):
    i = pl.program_id(0)
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _residual():
        r_ref[...] = (jnp.dot(xfull_ref[...], theta_ref[...],
                              preferred_element_type=r_ref.dtype)
                      - y_ref[...])

    @pl.when(i == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(xblk_ref[...].T, r_ref[...],
                          preferred_element_type=o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bm", "bq", "interpret"))
def linreg_grad(x, theta, y, *, bm: int = 128, bq: int = 128,
                interpret: bool = True):
    """g = X^T (X theta - Y): (m, q), (q, c), (m, c) -> (q, c)."""
    m, q = x.shape
    q2, c = theta.shape
    assert q == q2 and y.shape == (m, c)
    assert m % bm == 0 and q % bq == 0, (m, q, bm, bq)
    _check_c_fits_vmem(q, c, bm, bq, x.dtype)
    return pl.pallas_call(
        _kernel,
        grid=(m // bm, q // bq),
        in_specs=[
            pl.BlockSpec((bm, q), lambda i, j: (i, 0)),     # full-q row block
            pl.BlockSpec((q, c), lambda i, j: (0, 0)),      # theta resident
            pl.BlockSpec((bm, c), lambda i, j: (i, 0)),     # labels row block
            pl.BlockSpec((bm, bq), lambda i, j: (i, j)),    # X^T side tile
        ],
        out_specs=pl.BlockSpec((bq, c), lambda i, j: (j, 0)),
        out_shape=jax.ShapeDtypeStruct((q, c), x.dtype),
        scratch_shapes=[pltpu.VMEM((bm, c), x.dtype)],
        interpret=interpret,
    )(x, theta, y, x)


def _masked_kernel(xfull_ref, theta_ref, y_ref, mask_ref, xblk_ref, o_ref,
                   r_ref):
    i = pl.program_id(1)
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _residual():
        r = (jnp.dot(xfull_ref[0], theta_ref[...],
                     preferred_element_type=r_ref.dtype)
             - y_ref[0])
        r_ref[...] = r * mask_ref[0][:, None].astype(r_ref.dtype)

    @pl.when(i == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(xblk_ref[0].T, r_ref[...],
                          preferred_element_type=o_ref.dtype)[None]


@functools.partial(jax.jit, static_argnames=("bm", "bq", "interpret"))
def linreg_grad_masked(x, theta, y, mask, *, bm: int = 128, bq: int = 128,
                       interpret: bool = True):
    """Per-client masked gradients:  g_j = X_j^T diag(mask_j) (X_j theta - Y_j).

    x: (n, l, q), theta: (q, c), y: (n, l, c), mask: (n, l) -> (n, q, c).
    Grid (n, L/bm, Q/bq): the client axis is outermost, so one kernel call
    covers the whole dense mask-padded client tensor of the batched engine
    (including the fused parity pseudo-client row in the coded scheme).
    The mask multiplies the residual — rows with mask 0 contribute exactly
    zero regardless of the padded x/y contents, and fractional entries act
    as per-row gradient weights (the coded 1/u scale).
    """
    n, l, q = x.shape
    q2, c = theta.shape
    assert q == q2 and y.shape == (n, l, c) and mask.shape == (n, l)
    assert l % bm == 0 and q % bq == 0, (n, l, q, bm, bq)
    _check_c_fits_vmem(q, c, bm, bq, x.dtype)
    return pl.pallas_call(
        _masked_kernel,
        grid=(n, l // bm, q // bq),
        in_specs=[
            pl.BlockSpec((1, bm, q), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((q, c), lambda b, i, j: (0, 0)),
            pl.BlockSpec((1, bm, c), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, bm), lambda b, i, j: (b, i)),
            pl.BlockSpec((1, bm, bq), lambda b, i, j: (b, i, j)),
        ],
        out_specs=pl.BlockSpec((1, bq, c), lambda b, i, j: (b, j, 0)),
        out_shape=jax.ShapeDtypeStruct((n, q, c), x.dtype),
        scratch_shapes=[pltpu.VMEM((bm, c), x.dtype)],
        interpret=interpret,
    )(x, theta, y, mask, x)
