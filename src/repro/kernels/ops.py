"""jit'd public wrappers around the Pallas kernels with automatic padding
and a pure-jnp fallback.

`use_pallas=True` runs the Pallas kernels (interpret mode on CPU; compiled
on a real TPU where `interpret` should be set False by the caller).  The
default entry points pad inputs up to block multiples, run the kernel, and
slice the result back, so arbitrary shapes are accepted.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.linreg_grad import linreg_grad as _linreg_grad_kernel
from repro.kernels.linreg_grad import \
    linreg_grad_masked as _linreg_grad_masked_kernel
from repro.kernels.parity_encode import parity_encode as _parity_encode_kernel
from repro.kernels.parity_encode import \
    parity_encode_batched as _parity_encode_batched_kernel
from repro.kernels.rff_embed import rff_embed as _rff_embed_kernel
from repro.kernels.rff_linreg_grad import \
    rff_linreg_grad_masked as _rff_linreg_grad_masked_kernel
from repro.kernels.gqa_decode import gqa_decode as _gqa_decode_kernel


# TPU vector lanes: the last (minor) dim of every VMEM tile is 128 wide, so
# narrow label widths c are zero-padded up to a lane multiple before hitting
# the kernel (the hardware pads implicitly anyway; doing it explicitly keeps
# Mosaic from asserting on unsupported minor dims) and sliced back after.
_LANE = 128


def _pad_to(x, mults):
    """Zero-pad each dim of x up to the next multiple of mults[i]."""
    pads = []
    for dim, mult in zip(x.shape, mults):
        rem = (-dim) % mult
        pads.append((0, rem))
    if all(p == (0, 0) for p in pads):
        return x
    return jnp.pad(x, pads)


def _clamp_block(block: int, dim: int, interpret: bool, mult: int = 8) -> int:
    """Shrink a block size down to the (mult-rounded) dim — interpret only.

    The federated runtime's fused client+parity tensor often has a point
    axis far below the default 128-row block (e.g. l_max = 24); tiling it
    at the default would zero-pad every client row 5x, which interpret mode
    (CPU CI) pays for in real host FLOPs.  On a compiled TPU the defaults
    stay untouched: Mosaic requires 128-multiple lane dims there and the
    hardware pads implicitly anyway.
    """
    if not interpret:
        return block
    return max(mult, min(block, -(-dim // mult) * mult))


def rff_embed(x, omega, delta, *, use_pallas: bool = False,
              bm: int = 128, bq: int = 128, bk: int = 128,
              interpret: bool = True):
    if not use_pallas:
        return ref.rff_embed(x, omega, delta)
    m, d = x.shape
    q = omega.shape[1]
    xp = _pad_to(x, (bm, bk))
    op = _pad_to(omega, (bk, bq))
    dp = _pad_to(delta, (bq,))
    out = _rff_embed_kernel(xp, op, dp, bm=bm, bq=bq, bk=bk,
                            interpret=interpret, q_true=q)
    return out[:m, :q]


def rff_embed_batched(x_stack, omega, delta, *, use_pallas: bool = False,
                      bm: int = 128, bq: int = 128, bk: int = 128,
                      interpret: bool = True):
    """vmap-compatible RFF embedding over a client axis.

    x_stack: (n, l, d), omega: (d, q), delta: (q,) -> (n, l, q).  The jnp
    path vmaps the reference map; the Pallas path flattens the client axis
    into the row dimension so the whole stack is ONE tiled kernel call (one
    padding round instead of n).
    """
    if not use_pallas:
        return jax.vmap(lambda x: ref.rff_embed(x, omega, delta))(x_stack)
    n, l, d = x_stack.shape
    q = omega.shape[1]
    flat = rff_embed(x_stack.reshape(n * l, d), omega, delta,
                     use_pallas=True, bm=bm, bq=bq, bk=bk,
                     interpret=interpret)
    return flat.reshape(n, l, q)


def linreg_grad(x, theta, y, *, use_pallas: bool = False,
                bm: int = 128, bq: int = 128, interpret: bool = True):
    if not use_pallas:
        return ref.linreg_grad(x, theta, y)
    m, q = x.shape
    c = theta.shape[1]
    xp = _pad_to(x, (bm, bq))
    tp = _pad_to(theta, (bq, _LANE))
    yp = _pad_to(y, (bm, _LANE))
    out = _linreg_grad_kernel(xp, tp, yp, bm=bm, bq=bq, interpret=interpret)
    return out[:q, :c]


def linreg_grad_masked(x_stack, theta, y_stack, mask, *,
                       use_pallas: bool = False, bm: int = 128, bq: int = 128,
                       interpret: bool = True):
    """Per-client row-masked gradients over a dense padded client axis.

    x_stack: (n, l, q), theta: (q, c), y_stack: (n, l, c), mask: (n, l)
    -> (n, q, c) with  g_j = X_j^T diag(mask_j) (X_j theta - Y_j).

    This is the batched engine's hot path: the federated runtime hands over
    its dense mask-padded client tensor — with the global parity set fused
    in as an extra pseudo-client row in the coded scheme — and the whole
    round's gradients come from ONE kernel call (client axis = outermost
    grid dim).  Padding rows carry mask 0, so the caller need not pre-zero
    them; mask entries may be arbitrary per-row *weights* (not just 0/1),
    which is how the coded-gradient 1/u scale rides along.  In interpret
    mode the row block is clamped down to the point axis so short fused
    layouts tile without 5x zero-padding.
    """
    if not use_pallas:
        return jax.vmap(
            lambda x, y, w: ref.linreg_grad_masked(x, theta, y, w))(
                x_stack, y_stack, mask)
    n, l, q = x_stack.shape
    c = theta.shape[1]
    bm = _clamp_block(bm, l, interpret)
    xp = _pad_to(x_stack, (1, bm, bq))
    tp = _pad_to(theta, (bq, _LANE))
    yp = _pad_to(y_stack, (1, bm, _LANE))
    mp = _pad_to(mask, (1, bm))
    out = _linreg_grad_masked_kernel(xp, tp, yp, mp, bm=bm, bq=bq,
                                     interpret=interpret)
    return out[:, :q, :c]


def rff_linreg_grad_masked(x_raw, omega, delta, theta, y_stack, mask, *,
                           parity_phi=None, use_pallas: bool = False,
                           bm: int = 128, bq: int = 128,
                           interpret: bool = True):
    """Fused RFF-embed -> per-client masked gradients from RAW features.

    x_raw: (n, l, d) raw client features, omega: (d, q), delta: (q,),
    theta: (q, c), y_stack: (rows, l, c), mask: (rows, l) -> (rows, q, c)
    float32 with  g_j = phi_j^T diag(mask_j) (phi_j theta - Y_j)  and
    phi_j = sqrt(2/q) cos(X_j omega + delta).

    When `parity_phi` (l, q) is given, the coded parity pseudo-client rides
    along as one extra grid row (rows = n + 1): it is already embedded (a
    generator-weighted sum of embedded points lives in q-space), so the
    kernel substitutes it for the in-kernel embed and its mask entries carry
    the coded 1/u scale.  The (n, l, q) embedded tensor is never
    materialized in HBM — this replaces the two-pass rff_embed_batched +
    linreg_grad_masked round path.  bf16 inputs accumulate in f32 and the
    output is float32 either way; the jnp fallback upcasts to f32 up front
    to match.
    """
    n, l, d = x_raw.shape
    q = omega.shape[1]
    c = theta.shape[1]
    rows = n + (1 if parity_phi is not None else 0)
    assert y_stack.shape == (rows, l, c), (y_stack.shape, rows, l, c)
    assert mask.shape == (rows, l), (mask.shape, rows, l)
    if not use_pallas:
        f32 = jnp.float32
        phi = jax.vmap(lambda x: ref.rff_embed(
            x.astype(f32), omega.astype(f32), delta.astype(f32)))(x_raw)
        if parity_phi is not None:
            phi = jnp.concatenate([phi, parity_phi[None].astype(f32)], axis=0)
        return jax.vmap(lambda x, y, w: ref.linreg_grad_masked(
            x, theta.astype(f32), y.astype(f32), w.astype(f32)))(
                phi, y_stack, mask)
    bm = _clamp_block(bm, l, interpret)
    xp = _pad_to(x_raw, (1, bm, _LANE))
    if parity_phi is not None:
        # the parity grid row never embeds, but its raw-x block is still
        # fetched by the BlockSpec — give it a zero dummy row
        xp = jnp.concatenate([xp, jnp.zeros_like(xp[:1])], axis=0)
    op = _pad_to(omega, (_LANE, bq))
    dp = _pad_to(delta, (bq,))
    tp = _pad_to(theta, (bq, _LANE))
    yp = _pad_to(y_stack, (1, bm, _LANE))
    mp = _pad_to(mask, (1, bm))
    lp, qp = xp.shape[1], op.shape[1]
    if parity_phi is not None:
        pp = _pad_to(parity_phi, (bm, bq))[None]
    else:
        pp = jnp.zeros((1, lp, qp), x_raw.dtype)
    out = _rff_linreg_grad_masked_kernel(xp, op, dp, tp, yp, mp, pp,
                                         n_real=n, bm=bm, bq=bq,
                                         interpret=interpret, q_true=q)
    return out[:, :q, :c]


def linreg_grad_batched(x_stack, theta, y_stack, *, use_pallas: bool = False,
                        bm: int = 128, bq: int = 128, interpret: bool = True):
    """Per-client gradients over a dense client axis.

    x_stack: (n, l, q), theta: (q, c), y_stack: (n, l, c) -> (n, q, c).
    The jnp path vmaps the reference kernel (one fused batched matmul); the
    Pallas path is the masked batched kernel with an all-ones mask, i.e. one
    tiled kernel call for all n clients.
    """
    if not use_pallas:
        return jax.vmap(lambda x, y: ref.linreg_grad(x, theta, y))(
            x_stack, y_stack)
    mask = jnp.ones(x_stack.shape[:2], x_stack.dtype)
    return linreg_grad_masked(x_stack, theta, y_stack, mask, use_pallas=True,
                              bm=bm, bq=bq, interpret=interpret)


def parity_encode(g, w, x, *, use_pallas: bool = False,
                  bu: int = 128, bq: int = 128, bl: int = 128,
                  interpret: bool = True):
    if not use_pallas:
        return ref.parity_encode(g, w, x)
    u, l = g.shape
    q = x.shape[1]
    gp = _pad_to(g, (bu, bl))
    wp = _pad_to(w, (bl,))
    xp = _pad_to(x, (bl, bq))
    out = _parity_encode_kernel(gp, wp, xp, bu=bu, bq=bq, bl=bl,
                                interpret=interpret)
    return out[:u, :q]


def parity_encode_batched(g_stack, w_stack, x_stack, *,
                          use_pallas: bool = False, bu: int = 128,
                          bq: int = 128, bl: int = 128,
                          interpret: bool = True):
    """All-clients parity encode over a dense client axis.

    g_stack: (n, u, l), w_stack: (n, l), x_stack: (n, l, q) -> (n, u, q)
    with  parity_j = G_j diag(w_j) X_j.  The jnp path vmaps the reference
    kernel; the Pallas path is ONE tiled kernel launch with the client axis
    as the outermost grid dimension (in interpret mode, row blocks are
    clamped to the true u so small populations don't pad up to 128).
    """
    if not use_pallas:
        return jax.vmap(ref.parity_encode)(g_stack, w_stack, x_stack)
    n, u, l = g_stack.shape
    q = x_stack.shape[2]
    bu = _clamp_block(bu, u, interpret)
    gp = _pad_to(g_stack, (1, bu, bl))
    wp = _pad_to(w_stack, (1, bl))
    xp = _pad_to(x_stack, (1, bl, bq))
    out = _parity_encode_batched_kernel(gp, wp, xp, bu=bu, bq=bq, bl=bl,
                                        interpret=interpret)
    return out[:, :u, :q]


def gqa_decode(q, k, v, k_pos, q_pos, *, window: int = 0,
               use_pallas: bool = False, bt: int = 512,
               interpret: bool = True):
    if not use_pallas:
        return ref.gqa_decode(q, k, v, k_pos, q_pos, window)
    T = k.shape[1]
    # clamp through the 8-multiple helper: a bare min(bt, T) can leave a
    # non-multiple-of-8 block (T=500 -> bt=500) that only interpret mode
    # tolerates; _clamp_block rounds to an aligned tile and leaves the
    # compiled path's block untouched
    bt = _clamp_block(bt, T, interpret)
    rem = (-T) % bt
    if rem:
        k = jnp.pad(k, ((0, 0), (0, rem), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, rem), (0, 0), (0, 0)))
        k_pos = jnp.pad(k_pos, (0, rem), constant_values=-1)
    return _gqa_decode_kernel(q, k, v, k_pos, q_pos, bt=bt, window=window,
                              interpret=interpret)
