"""jit'd public wrappers around the Pallas kernels with automatic padding
and a pure-jnp fallback.

`use_pallas=True` runs the Pallas kernels (interpret mode on CPU; compiled
on a real TPU where `interpret` should be set False by the caller).  The
default entry points pad inputs up to block multiples, run the kernel, and
slice the result back, so arbitrary shapes are accepted.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.linreg_grad import linreg_grad as _linreg_grad_kernel
from repro.kernels.parity_encode import parity_encode as _parity_encode_kernel
from repro.kernels.rff_embed import rff_embed as _rff_embed_kernel
from repro.kernels.gqa_decode import gqa_decode as _gqa_decode_kernel


def _pad_to(x, mults):
    """Zero-pad each dim of x up to the next multiple of mults[i]."""
    pads = []
    for dim, mult in zip(x.shape, mults):
        rem = (-dim) % mult
        pads.append((0, rem))
    if all(p == (0, 0) for p in pads):
        return x
    return jnp.pad(x, pads)


def rff_embed(x, omega, delta, *, use_pallas: bool = False,
              bm: int = 128, bq: int = 128, bk: int = 128,
              interpret: bool = True):
    if not use_pallas:
        return ref.rff_embed(x, omega, delta)
    m, d = x.shape
    q = omega.shape[1]
    xp = _pad_to(x, (bm, bk))
    op = _pad_to(omega, (bk, bq))
    dp = _pad_to(delta, (bq,))
    out = _rff_embed_kernel(xp, op, dp, bm=bm, bq=bq, bk=bk,
                            interpret=interpret, q_true=q)
    return out[:m, :q]


def linreg_grad(x, theta, y, *, use_pallas: bool = False,
                bm: int = 128, bq: int = 128, interpret: bool = True):
    if not use_pallas:
        return ref.linreg_grad(x, theta, y)
    m, q = x.shape
    c = theta.shape[1]
    xp = _pad_to(x, (bm, bq))
    tp = _pad_to(theta, (bq, 1))
    yp = _pad_to(y, (bm, 1))
    out = _linreg_grad_kernel(xp, tp, yp, bm=bm, bq=bq, interpret=interpret)
    return out[:q, :c]


def linreg_grad_batched(x_stack, theta, y_stack, *, use_pallas: bool = False,
                        bm: int = 128, bq: int = 128, interpret: bool = True):
    """Per-client gradients over a dense client axis.

    x_stack: (n, l, q), theta: (q, c), y_stack: (n, l, c) -> (n, q, c).
    The jnp path vmaps the reference kernel (one fused batched matmul);
    the Pallas path runs the tiled kernel per client so each call keeps its
    own padding to block multiples.
    """
    if not use_pallas:
        return jax.vmap(lambda x, y: ref.linreg_grad(x, theta, y))(
            x_stack, y_stack)
    return jnp.stack([
        linreg_grad(x_stack[j], theta, y_stack[j], use_pallas=True,
                    bm=bm, bq=bq, interpret=interpret)
        for j in range(x_stack.shape[0])])


def parity_encode(g, w, x, *, use_pallas: bool = False,
                  bu: int = 128, bq: int = 128, bl: int = 128,
                  interpret: bool = True):
    if not use_pallas:
        return ref.parity_encode(g, w, x)
    u, l = g.shape
    q = x.shape[1]
    gp = _pad_to(g, (bu, bl))
    wp = _pad_to(w, (bl,))
    xp = _pad_to(x, (bl, bq))
    out = _parity_encode_kernel(gp, wp, xp, bu=bu, bq=bq, bl=bl,
                                interpret=interpret)
    return out[:u, :q]


def gqa_decode(q, k, v, k_pos, q_pos, *, window: int = 0,
               use_pallas: bool = False, bt: int = 512,
               interpret: bool = True):
    if not use_pallas:
        return ref.gqa_decode(q, k, v, k_pos, q_pos, window)
    T = k.shape[1]
    bt = min(bt, T)
    rem = (-T) % bt
    if rem:
        k = jnp.pad(k, ((0, 0), (0, rem), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, rem), (0, 0), (0, 0)))
        k_pos = jnp.pad(k_pos, (0, rem), constant_values=-1)
    return _gqa_decode_kernel(q, k, v, k_pos, q_pos, bt=bt, window=window,
                              interpret=interpret)
