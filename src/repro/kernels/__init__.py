"""Pallas TPU kernels for CodedFedL's compute hot-spots.

  rff_embed     -- fused cos(X @ Omega + delta) RFF map (paper eq. 18)
  linreg_grad   -- fused X^T (X theta - Y) gradient (paper eq. 7/10/28)
  parity_encode -- fused G diag(w) X parity encoding (paper eq. 19)
  gqa_decode    -- flash-decode GQA attention (serving hot-spot, SPerf it. 2)

Each kernel has a pure-jnp oracle in ref.py; ops.py holds jit'd wrappers
with padding + fallback.  Kernels target TPU v5e BlockSpec/VMEM tiling and
are validated on CPU in interpret mode.
"""
from repro.kernels import ops, ref

__all__ = ["ops", "ref"]
