"""Pallas TPU kernels for CodedFedL's compute hot-spots.

  rff_embed           -- fused cos(X @ Omega + delta) RFF map (paper eq. 18)
  linreg_grad         -- fused X^T (X theta - Y) gradient (eq. 7/10/28)
  linreg_grad_masked  -- batched row-masked gradient over the dense padded
                         (n, l_max, q) client tensor (the batched engine's
                         kernel_backend="pallas" hot path)
  parity_encode       -- fused G diag(w) X parity encoding (paper eq. 19)
  gqa_decode          -- flash-decode GQA attention (serving hot-spot)

Each kernel has a pure-jnp oracle in ref.py; ops.py holds jit'd wrappers
with padding + fallback (plus the vmap-compatible batched entry points
linreg_grad_batched / rff_embed_batched).  Kernels target TPU v5e
BlockSpec/VMEM tiling and are validated on CPU in interpret mode
(tests/test_kernels.py, marked `kernels`).
"""
from repro.kernels import ops, ref

__all__ = ["ops", "ref"]
