"""Non-IID client data partition (paper §V-A).

The paper sorts the training set by class label, cuts it into n equal
shards, sorts the clients by their expected round time (eq. 15 with the
local minibatch size), and hands shards out in that order — so the slowest
clients own entire classes and 'greedy uncoded' systematically misses them.
"""
from __future__ import annotations

import numpy as np

from repro.core.delay_model import NodeDelayParams


def sort_and_shard(x: np.ndarray, y: np.ndarray, n_clients: int):
    """Sort by label, split into n equal shards.  Returns list of (x, y)."""
    order = np.argsort(y, kind="stable")
    x, y = x[order], y[order]
    m = (x.shape[0] // n_clients) * n_clients
    xs = np.split(x[:m], n_clients)
    ys = np.split(y[:m], n_clients)
    return list(zip(xs, ys))


def assign_shards_by_speed(shards, nodes: list[NodeDelayParams],
                           minibatch: int):
    """Assign label-sorted shards to clients ordered by expected delay.

    Client order: ascending E[T_j] at load = local minibatch size (paper
    §V-A).  Returns per-client (x, y) in client index order.
    """
    exp_delay = np.array([nd.expected_delay(minibatch) for nd in nodes])
    client_order = np.argsort(exp_delay)
    out = [None] * len(nodes)
    for shard_idx, client in enumerate(client_order):
        out[client] = shards[shard_idx]
    return out


def stack_clients(per_client):
    """List of (x, y) with equal sizes -> (n, l, d), (n, l) arrays."""
    xs = np.stack([c[0] for c in per_client])
    ys = np.stack([c[1] for c in per_client])
    return xs, ys
