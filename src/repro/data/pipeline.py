"""Deterministic, shardable LM data pipeline.

Documents (synthetic Zipf streams standing in for tokenized text) are packed
into fixed-length sequences with EOS separators; labels are next-token
targets with -100 on the final position of each sequence and across document
boundaries optionally masked.  The pipeline is *stateless*: `batch_at(step)`
is a pure function of (seed, shard, step), so training resume needs only the
step counter — no iterator state in checkpoints.
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class PipelineConfig:
    vocab: int
    seq_len: int
    batch: int                   # per-shard batch size
    seed: int = 0
    n_shards: int = 1
    shard_id: int = 0
    eos_id: int = 0
    mean_doc_len: int = 96
    mask_cross_doc: bool = True


class PackedLMDataset:
    def __init__(self, cfg: PipelineConfig):
        assert 0 <= cfg.shard_id < cfg.n_shards
        self.cfg = cfg
        ranks = np.arange(1, cfg.vocab, dtype=np.float64)   # reserve eos=0
        p = 1.0 / ranks
        self._probs = p / p.sum()

    def _doc(self, rng: np.random.Generator) -> np.ndarray:
        n = max(2, int(rng.exponential(self.cfg.mean_doc_len)))
        toks = rng.choice(self.cfg.vocab - 1, size=n, p=self._probs) + 1
        return np.concatenate([toks, [self.cfg.eos_id]]).astype(np.int32)

    def _packed_row(self, rng: np.random.Generator):
        """One packed row of seq_len+1 tokens + doc-boundary marks."""
        S = self.cfg.seq_len + 1
        buf = np.empty(S, np.int32)
        bounds = np.zeros(S, bool)
        i = 0
        while i < S:
            d = self._doc(rng)
            take = min(len(d), S - i)
            buf[i:i + take] = d[:take]
            if i > 0:
                bounds[i] = True            # first token of a new doc
            i += take
        return buf, bounds

    def batch_at(self, step: int) -> dict[str, np.ndarray]:
        """Pure function of (seed, shard_id, step) -> {tokens, labels}."""
        cfg = self.cfg
        out_t = np.empty((cfg.batch, cfg.seq_len), np.int32)
        out_l = np.empty((cfg.batch, cfg.seq_len), np.int32)
        for b in range(cfg.batch):
            key = (cfg.seed, cfg.shard_id, step, b)
            rng = np.random.default_rng(abs(hash(key)) % (2 ** 63))
            row, bounds = self._packed_row(rng)
            out_t[b] = row[:-1]
            labels = row[1:].copy()
            if cfg.mask_cross_doc:
                labels[bounds[1:]] = -100   # don't predict across docs
            out_l[b] = labels
        return {"tokens": out_t, "labels": out_l}

    def __iter__(self):
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1


def shard_pipelines(vocab: int, seq_len: int, global_batch: int,
                    n_shards: int, seed: int = 0) -> list[PackedLMDataset]:
    """One pipeline per data shard (or simulated FL client)."""
    assert global_batch % n_shards == 0
    return [PackedLMDataset(PipelineConfig(
        vocab=vocab, seq_len=seq_len, batch=global_batch // n_shards,
        seed=seed, n_shards=n_shards, shard_id=i)) for i in range(n_shards)]
