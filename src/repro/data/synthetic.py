"""Synthetic benchmark datasets.

MNIST / Fashion-MNIST are not downloadable in this container, so the
experiment drivers use a statistically matched stand-in: a c-class Gaussian
mixture in R^d with class means drawn on a sphere, features normalized to
[0, 1] exactly as the paper normalizes pixel intensities.  The non-IID
partition (sort-by-label + shard) and every wall-clock quantity are
unaffected by this substitution (see DESIGN.md §7).
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass
class Dataset:
    x_train: np.ndarray     # (m, d) in [0, 1]
    y_train: np.ndarray     # (m,) int labels
    x_test: np.ndarray
    y_test: np.ndarray
    n_classes: int

    def one_hot(self, y: np.ndarray) -> np.ndarray:
        out = np.zeros((y.shape[0], self.n_classes), np.float32)
        out[np.arange(y.shape[0]), y] = 1.0
        return out


def synthetic_classification(m_train: int = 12000, m_test: int = 2000,
                             d: int = 784, n_classes: int = 10,
                             class_sep: float = 2.2, intra_dim: int = 24,
                             seed: int = 0) -> Dataset:
    """MNIST-like task: c Gaussian clusters on low-dim manifolds in R^d.

    class_sep controls difficulty; with the defaults a linear model reaches
    ~85-90% and an RBF-kernel (RFF) model a few points more — mirroring the
    MNIST linear-vs-kernel gap the paper exploits.
    """
    rng = np.random.default_rng(seed)
    means = rng.normal(size=(n_classes, d))
    means *= class_sep / np.linalg.norm(means, axis=1, keepdims=True)
    # shared low-rank within-class covariance factors (nonlinear structure)
    factors = rng.normal(size=(n_classes, d, intra_dim)) / np.sqrt(d)

    def sample(n):
        y = rng.integers(0, n_classes, size=n)
        z = rng.normal(size=(n, intra_dim))
        x = means[y] + np.einsum("nij,nj->ni", factors[y], z)
        # mild class-dependent nonlinearity so the RBF kernel has an edge
        x = x + 0.35 * np.tanh(2.0 * x) * (1.0 + 0.1 * y[:, None])
        x += 0.25 * rng.normal(size=x.shape)
        return x.astype(np.float32), y.astype(np.int64)

    x_tr, y_tr = sample(m_train)
    x_te, y_te = sample(m_test)
    # normalize features to [0, 1] using train stats (paper §V-A)
    lo = x_tr.min(axis=0, keepdims=True)
    hi = x_tr.max(axis=0, keepdims=True)
    span = np.maximum(hi - lo, 1e-6)
    x_tr = (x_tr - lo) / span
    x_te = np.clip((x_te - lo) / span, 0.0, 1.0)
    return Dataset(x_tr, y_tr, x_te, y_te, n_classes)


def synthetic_tokens(vocab: int, batch: int, seq: int, seed: int = 0) -> np.ndarray:
    """Token batches for LM smoke training (Zipf-ish distribution)."""
    rng = np.random.default_rng(seed)
    ranks = np.arange(1, vocab + 1, dtype=np.float64)
    p = 1.0 / ranks
    p /= p.sum()
    return rng.choice(vocab, size=(batch, seq), p=p).astype(np.int32)
