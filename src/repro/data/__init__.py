"""Data substrate: synthetic datasets, non-IID sharding, LM pipelines."""
from repro.data import pipeline, sharding, synthetic

__all__ = ["pipeline", "sharding", "synthetic"]
